"""LMServingEngine integration: serve two reduced-LM variants from the
deduplicated page store (weights faulted through the buffer pool)."""
import jax
import numpy as np
import pytest


@pytest.mark.slow
def test_lm_engine_generates_from_dedup_store():
    from repro.configs import get_config, reduced
    from repro.core import DedupConfig, LSHConfig, ModelStore, StoreConfig
    from repro.models import build
    from repro.serving.engine import (LMServingEngine, StorageModel,
                                      WeightServer)

    cfg = reduced(get_config("deepseek-7b"))
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0), 64)

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)

    def key_of(path):
        return "/".join(str(getattr(p, "key", p)) for p in path)

    tensors = {key_of(p): np.asarray(l, np.float32).reshape(l.shape[0], -1)
               if l.ndim > 2 else np.asarray(l, np.float32)
               for p, l in flat}
    shapes = {key_of(p): l.shape for p, l in flat}
    dtypes = {key_of(p): l.dtype for p, l in flat}

    store = ModelStore(StoreConfig(
        dedup=DedupConfig(block_shape=(32, 32),
                          lsh=LSHConfig(num_bands=8, rows_per_band=2,
                                        r=4.0, collision_threshold=6),
                          validate=False),
        blocks_per_page=8))
    store.register("lm-v0", tensors)
    store.register("lm-v1", {k: v + 1e-5 for k, v in tensors.items()})
    assert store.storage_bytes() < store.dense_bytes()

    def rebuild(ts):
        import jax.numpy as jnp
        leaves = [jnp.asarray(ts[key_of(p)].reshape(shapes[key_of(p)]),
                              dtypes[key_of(p)]) for p, l in flat]
        return jax.tree_util.tree_unflatten(treedef, leaves)

    server = WeightServer(store, capacity_pages=max(2, store.num_pages() // 2),
                          storage=StorageModel("ssd"))
    engine = LMServingEngine(server, {"lm-v0": api, "lm-v1": api},
                             {m: {"rebuild": rebuild}
                              for m in ("lm-v0", "lm-v1")})
    prompts = np.ones((2, 8), np.int32)
    out0, _ = engine.generate("lm-v0", prompts, steps=4)
    out1, _ = engine.generate("lm-v1", prompts, steps=4)
    assert out0.shape == (2, 4) and out1.shape == (2, 4)
    # model switch faulted pages through the pool
    assert server.pool.hits + server.pool.misses > 0
    assert engine.stats.batches == 2
