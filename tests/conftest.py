import os
import sys

# src-layout import without install
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Smoke tests must see exactly ONE device — never set
# --xla_force_host_platform_device_count here (dry-run tests spawn
# subprocesses with REPRO_DRYRUN_DEVICES instead).
assert "xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", "")

# REPRO_SANITIZE=1 runs the whole suite under the PoolSanitizer: every
# BufferPool/DevicePagePool/ShardedPagePool constructed from here on is
# born instrumented, and any protocol violation (stale-remap read,
# evict-while-pinned, missed generation bump, non-owner shard load, ...)
# raises at the violating call site.  See DESIGN.md §7.
if os.environ.get("REPRO_SANITIZE", "") == "1":
    import repro.analysis.sanitizer  # noqa: F401  (self-enables, strict)

# REPRO_FAULTS=<spec> runs the suite under seeded storage-fault
# injection: every backend opened by URL/path (ModelStore.save/open
# attach points) is wrapped in a FaultInjectingBackend with this spec,
# and the recovery layer (retry + verify + quarantine, DESIGN.md §8)
# must keep every test green anyway.  The env var is read directly by
# repro.storage.faults.global_fault_spec() at each wrap point — no
# import or registration needed here; this note is the contract.
# Explicitly constructed backend INSTANCES are never wrapped, so tests
# asserting exact backend call counts stay deterministic.
