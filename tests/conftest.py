import os
import sys

# src-layout import without install
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Smoke tests must see exactly ONE device — never set
# --xla_force_host_platform_device_count here (dry-run tests spawn
# subprocesses with REPRO_DRYRUN_DEVICES instead).
assert "xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", "")
