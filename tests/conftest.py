import os
import sys

# src-layout import without install
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Smoke tests must see exactly ONE device — never set
# --xla_force_host_platform_device_count here (dry-run tests spawn
# subprocesses with REPRO_DRYRUN_DEVICES instead).
assert "xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", "")

# REPRO_SANITIZE=1 runs the whole suite under the PoolSanitizer: every
# BufferPool/DevicePagePool/ShardedPagePool constructed from here on is
# born instrumented, and any protocol violation (stale-remap read,
# evict-while-pinned, missed generation bump, non-owner shard load, ...)
# raises at the violating call site.  See DESIGN.md §7.
if os.environ.get("REPRO_SANITIZE", "") == "1":
    import repro.analysis.sanitizer  # noqa: F401  (self-enables, strict)
