"""Request-level serving front end tests (traffic.py + frontend.py):
seeded open-loop generator (Poisson arrivals, Zipf popularity, stream
continuation), the --traffic spec grammar, virtual-clock channel
accounting, SLO-driven batch formation / forced dispatch / shedding vs
the naive per-arrival control, the frontend->prefetcher λ feed tracking
a shifted Zipf, and the acceptance bit-equality: frontend-served logits
== direct engine submission (embedding + LM, 1 and 2 shards).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import DedupConfig, LSHConfig, ModelStore, StoreConfig
from repro.data.pipeline import SyntheticTextTask
from repro.launch.serve import build_store
from repro.serving import (BatchComputeModel, EmbeddingServingEngine,
                           LMServingEngine, OpenLoopTraffic, Prefetcher,
                           Request, ServeStats, ServingFrontend,
                           ShardedWeightServer, StorageModel, TrafficSpec,
                           VirtualClock, WeightServer, zipf_weights,
                           zoo_popularity)


def _scenario(vocab=512, d=32, num_models=3, block=(32, 32), l=4, seed=0):
    task = SyntheticTextTask(vocab=vocab, d=d, seed=seed)
    store, heads = build_store(task, num_models=num_models,
                               block_shape=block, blocks_per_page=l)
    return task, store, heads


def _doc_payload(task, docs_per_req=3, seed_base=700):
    def payload(model, rid, rng):
        v = int(model.rsplit("-v", 1)[1])
        docs, _ = task.sample(docs_per_req, variant=v,
                              seed=seed_base + rid)
        return docs
    return payload


def _requests(model, payloads, arrivals, slo):
    return [Request(rid=i, model=model, payload=p, arrival_t=t,
                    deadline=t + slo)
            for i, (p, t) in enumerate(zip(payloads, arrivals))]


# -------------------------------------------------------------- generator --
def test_generator_deterministic_under_seed():
    models = ["m0", "m1", "m2"]
    a = OpenLoopTraffic(models, rate=100.0, seed=4).generate(50)
    b = OpenLoopTraffic(models, rate=100.0, seed=4).generate(50)
    assert [(r.rid, r.model, r.arrival_t, r.deadline) for r in a] \
        == [(r.rid, r.model, r.arrival_t, r.deadline) for r in b]
    c = OpenLoopTraffic(models, rate=100.0, seed=5).generate(50)
    assert [r.arrival_t for r in a] != [r.arrival_t for r in c]


def test_generator_stream_continues_across_calls():
    models = ["m0", "m1"]
    gen = OpenLoopTraffic(models, rate=50.0, seed=2)
    split = gen.generate(10) + gen.generate(10)
    whole = OpenLoopTraffic(models, rate=50.0, seed=2).generate(20)
    assert [(r.rid, r.model, r.arrival_t) for r in split] \
        == [(r.rid, r.model, r.arrival_t) for r in whole]
    # arrivals are strictly increasing across the call boundary
    ts = [r.arrival_t for r in split]
    assert all(t1 > t0 for t0, t1 in zip(ts, ts[1:]))


def test_poisson_mean_interarrival_tracks_rate():
    gen = OpenLoopTraffic(["m"], rate=200.0, seed=0)
    reqs = gen.generate(4000)
    gaps = np.diff([0.0] + [r.arrival_t for r in reqs])
    assert np.mean(gaps) == pytest.approx(1.0 / 200.0, rel=0.1)


def test_zipf_popularity_skews_to_head_rank():
    models = [f"m{i}" for i in range(5)]
    reqs = OpenLoopTraffic(models, rate=100.0, zipf_alpha=1.5,
                           seed=1).generate(3000)
    counts = {m: 0 for m in models}
    for r in reqs:
        counts[r.model] += 1
    assert counts["m0"] == max(counts.values())
    assert counts["m0"] > 3 * counts["m4"]


def test_zipf_weights_shape_and_degenerate_alpha():
    w = zipf_weights(4, 1.0)
    assert w.sum() == pytest.approx(1.0)
    assert all(a > b for a, b in zip(w, w[1:]))
    np.testing.assert_allclose(zipf_weights(4, 0.0), np.full(4, 0.25))
    with pytest.raises(ValueError):
        zipf_weights(0, 1.0)


def test_zoo_popularity_covers_registry_in_rank_order():
    pop = zoo_popularity(alpha=1.2)
    from repro.configs import list_archs
    assert list(pop) == list(list_archs())
    assert sum(pop.values()) == pytest.approx(1.0)
    vals = list(pop.values())
    assert all(a > b for a, b in zip(vals, vals[1:]))


def test_generator_rejects_nonpositive_rate():
    with pytest.raises(ValueError):
        OpenLoopTraffic(["m"], rate=0.0)


# ------------------------------------------------------------ spec grammar --
def test_traffic_spec_parse_roundtrip_and_defaults():
    spec = TrafficSpec.parse("rate=500,zipf=1.3,slo_ms=25,seed=7")
    assert (spec.rate, spec.zipf, spec.slo_ms, spec.seed) \
        == (500.0, 1.3, 25.0, 7)
    assert spec.requests == 200 and spec.max_batch == 8   # defaults ride
    assert TrafficSpec.parse(str(spec)) == spec
    assert TrafficSpec.parse("") == TrafficSpec()
    assert TrafficSpec.parse(None) == TrafficSpec()
    assert str(TrafficSpec()) == "default"
    assert "requests" not in str(spec)                    # defaults omitted
    assert TrafficSpec.parse(spec) is spec


@pytest.mark.parametrize("bad", ["rate", "volume=3", "rate=0",
                                 "slo_ms=-1", "rate=two"])
def test_traffic_spec_rejects_malformed(bad):
    with pytest.raises(ValueError):
        TrafficSpec.parse(bad)


# ------------------------------------------------------------------ clock --
def test_virtual_clock_channel_accounting():
    clk = VirtualClock()
    clk.advance(0.5, "storage")
    clk.advance(0.25, "compute")
    clk.tick_to(1.0)                       # 0.25s of idle
    clk.tick_to(0.5)                       # past: no-op
    assert clk.now == pytest.approx(1.0)
    assert clk.spent("storage") == pytest.approx(0.5)
    assert clk.spent("idle") == pytest.approx(0.25)
    assert sum(clk.channels.values()) == pytest.approx(clk.now)
    with pytest.raises(ValueError):
        clk.advance(-0.1, "storage")


# -------------------------------------------------------------- formation --
def _frontend(task, store, heads, *, policy="slo", max_batch=4,
              storage="dram", cap=None):
    server = WeightServer(store, cap or store.num_pages(),
                          storage=StorageModel(storage))
    engine = EmbeddingServingEngine(server, heads, scheduler="fifo")
    return ServingFrontend(engine, max_batch=max_batch, policy=policy,
                           compute_model=BatchComputeModel())


def test_formation_closes_batches_at_max_batch():
    task, store, heads = _scenario()
    fe = _frontend(task, store, heads, max_batch=4)
    docs = [task.sample(2, variant=0, seed=s)[0] for s in range(8)]
    st = fe.run(_requests("word2vec-v0", docs, [0.0] * 8, slo=10.0))
    assert st.batches == 2                       # 8 requests / max_batch 4
    assert [len(b) for _, b in fe.dispatched] == [4, 4]
    assert st.shed_requests == 0 and len(st.request_latencies) == 8
    assert st.goodput == 1.0


def test_forced_dispatch_merges_then_beats_deadline():
    """A sub-max_batch queue is held open to merge a later arrival, but
    the slack rule forces dispatch before the oldest deadline dies.
    Pages are pre-warmed so the service estimate is exact (pure compute
    model): dispatching at the last forced instant then lands the batch
    exactly on the deadline, never past it."""
    task, store, heads = _scenario()
    fe = _frontend(task, store, heads, max_batch=4)
    docs = [task.sample(2, variant=0, seed=s)[0] for s in range(2)]
    server = fe.engine.server
    rows = np.unique(np.concatenate([d.reshape(-1) for d in docs]))
    for p in server.embedding_rows_pages("word2vec-v0", "embedding", rows):
        server.pool.access("word2vec-v0", p)
    st = fe.run(_requests("word2vec-v0", docs, [0.0, 0.004], slo=0.05))
    assert st.batches == 1                       # merged into ONE batch
    assert len(fe.dispatched[0][1]) == 2
    assert st.slo_misses == 0                    # ... and still on time
    assert st.queue_latencies[0] > 0.0           # r0 actually waited


def test_shedding_drops_dead_on_arrival_requests():
    task, store, heads = _scenario()
    fe = _frontend(task, store, heads, storage="hdd",
                   cap=max(2, store.num_pages() // 2))
    docs, _ = task.sample(2, variant=0, seed=0)
    # an hdd group fetch costs ~10ms; a 1µs SLO is unservable
    st = fe.run(_requests("word2vec-v0", [docs], [0.0], slo=1e-6))
    assert st.shed_requests == 1
    assert st.request_latencies == [] and st.batches == 0
    assert st.offered_requests == 1 and st.goodput == 0.0


def test_naive_policy_dispatches_per_arrival():
    task, store, heads = _scenario()
    fe = _frontend(task, store, heads, policy="naive", max_batch=4)
    docs = [task.sample(2, variant=0, seed=s)[0] for s in range(6)]
    st = fe.run(_requests("word2vec-v0", docs, [0.0] * 6, slo=10.0))
    assert st.batches == 6                       # no formation, no merge
    assert all(len(b) == 1 for _, b in fe.dispatched)
    assert st.shed_requests == 0                 # ... and no shedding


def test_frontend_rejects_bad_policy_and_batch():
    task, store, heads = _scenario()
    server = WeightServer(store, store.num_pages(),
                          storage=StorageModel("dram"))
    engine = EmbeddingServingEngine(server, heads)
    with pytest.raises(ValueError):
        ServingFrontend(engine, policy="greedy")
    with pytest.raises(ValueError):
        ServingFrontend(engine, max_batch=0)


# ------------------------------------------------------------ stats guard --
def test_percentiles_raise_on_empty_latency_lists():
    st = ServeStats()
    with pytest.raises(ValueError, match="empty latency list"):
        st.percentile(50)
    with pytest.raises(ValueError, match="empty request-latency list"):
        st.request_percentile(99)
    assert st.goodput == 0.0                     # guard, not a raise


# ----------------------------------------------------------------- λ feed --
def test_prefetcher_plan_tracks_attached_rates():
    """The speculative tier follows the *observed* rate feed: when the
    Zipf head shifts, the plan re-targets immediately instead of
    waiting for pool access counts to catch up."""
    _, store, _ = _scenario()
    server = WeightServer(store, store.num_pages(),
                          storage=StorageModel("dram"))
    pf = Prefetcher(server, hot_models=1, max_pages_per_step=4,
                    lookahead=0)
    rates = {"word2vec-v2": 5.0, "word2vec-v0": 1.0}
    pf.attach_rates(lambda: dict(rates))
    plan = pf.plan()
    assert plan and all(m == "word2vec-v2" for m, _ in plan)
    rates = {"word2vec-v2": 1.0, "word2vec-v0": 5.0}      # the shift
    plan = pf.plan()
    assert plan and all(m == "word2vec-v0" for m, _ in plan)
    rates = {}                                   # empty feed: pool fallback
    server.pool.access("word2vec-v1", store.model_pages("word2vec-v1")[0])
    assert pf.plan()


def test_frontend_feeds_observed_rates_to_prefetcher():
    """End-to-end λ feed: the frontend auto-attaches its arrival-rate
    EMA, and after the traffic mix shifts Zipf head the feed's hottest
    model shifts with it."""
    task, store, heads = _scenario()
    server = WeightServer(store, max(2, store.num_pages() // 2),
                          storage=StorageModel("dram"))
    pf = Prefetcher(server, hot_models=1, lookahead=0)
    engine = EmbeddingServingEngine(server, heads, scheduler="fifo",
                                    prefetcher=pf, overlap=True)
    fe = ServingFrontend(engine, max_batch=4,
                         compute_model=BatchComputeModel())
    assert pf._rate_fn is not None               # auto-attached
    models = [f"word2vec-v{v}" for v in range(3)]
    payload = _doc_payload(task)
    fe.run(OpenLoopTraffic(models, rate=300.0, zipf_alpha=3.0, slo_s=1.0,
                           seed=3, payload_fn=payload).generate(80))
    r1 = fe.arrival_rates()
    assert max(r1, key=r1.get) == "word2vec-v0"
    # shift the Zipf head to v2 and continue on the same clock
    gen2 = OpenLoopTraffic(list(reversed(models)), rate=300.0,
                           zipf_alpha=3.0, slo_s=1.0, seed=4,
                           payload_fn=payload)
    t0 = fe.clock.now + 1e-3
    fe.run([dataclasses.replace(r, arrival_t=r.arrival_t + t0,
                                deadline=r.deadline + t0)
            for r in gen2.generate(80)])
    r2 = fe.arrival_rates()
    assert max(r2, key=r2.get) == "word2vec-v2"
    assert r2["word2vec-v2"] > r1.get("word2vec-v2", 0.0)


# ------------------------------------------------- acceptance bit-equality --
@pytest.mark.parametrize("shards", [1, 2])
def test_frontend_logits_match_direct_submission_embedding(shards):
    """Frontend-served logits are bit-identical to replaying the same
    batches through direct engine submission — formation and admission
    reorder work, they never touch the math (1 and 2 shards)."""
    task, store, heads = _scenario(vocab=512, num_models=4)
    cap = max(4, store.num_pages() - 2)

    def make():
        if shards == 1:
            server = WeightServer(store, cap,
                                  storage=StorageModel("dram"))
        else:
            server = ShardedWeightServer(store, cap,
                                         storage=StorageModel("dram"),
                                         shards=2, placement="sharers")
        return EmbeddingServingEngine(server, heads, scheduler="fifo")

    models = [f"word2vec-v{v}" for v in range(4)]
    gen = OpenLoopTraffic(models, rate=400.0, zipf_alpha=1.1, slo_s=0.5,
                          seed=5, payload_fn=_doc_payload(task))
    fe = ServingFrontend(make(), max_batch=4,
                         compute_model=BatchComputeModel())
    st = fe.run(gen.generate(40))
    assert st.shed_requests == 0 and len(fe.results) == 40

    engine2 = make()
    for model, kept in fe.dispatched:
        engine2.submit(model, np.concatenate(
            [np.asarray(r.payload) for r in kept], axis=0))
        engine2.run(max_batches=1)
        out = np.asarray(engine2.last_logits)
        row = 0
        for r in kept:
            n = np.asarray(r.payload).shape[0]
            np.testing.assert_array_equal(fe.results[r.rid],
                                          out[row: row + n])
            row += n


class _TinyLMAPI:
    """Minimal prefill/decode API over {emb, head} params (mirrors
    tests/test_transfer.py): deterministic, model-switch faults real."""

    def prefill(self, params, batch, max_len):
        import jax.numpy as jnp
        tokens = jnp.asarray(batch["tokens"])
        x = jnp.asarray(params["emb"])[tokens].mean(axis=1)
        logits = x @ jnp.asarray(params["head"])
        return logits[:, None, :], {"x": x}

    def decode(self, params, cache, tokens):
        import jax.numpy as jnp
        x = cache["x"] * 0.5 + jnp.asarray(params["emb"])[
            jnp.asarray(tokens)[:, 0]]
        logits = x @ jnp.asarray(params["head"])
        return logits[:, None, :], {"x": x}


def _lm_setup(seed=0):
    rng = np.random.default_rng(seed)
    vocab, d = 96, 32
    emb = (rng.standard_normal((vocab, d)) * 0.1).astype(np.float32)
    head = (rng.standard_normal((d, vocab)) * 0.1).astype(np.float32)
    store = ModelStore(StoreConfig(
        dedup=DedupConfig(block_shape=(16, 16),
                          lsh=LSHConfig(num_bands=8, rows_per_band=2,
                                        r=8.0, collision_threshold=6),
                          validate=False),
        blocks_per_page=4))
    names = []
    for v in range(3):
        name = f"lm-v{v}"
        names.append(name)
        emb_v = emb.copy()
        lo = v * vocab // 3                  # private stripe per variant
        emb_v[lo:lo + vocab // 3] += (
            rng.standard_normal((vocab // 3, d)) * 0.3).astype(np.float32)
        store.register(name, {"emb": emb_v, "head": head})
    api = _TinyLMAPI()
    return store, names, {n: api for n in names}, \
        {n: {"rebuild": lambda ts: dict(ts)} for n in names}


def test_frontend_tokens_match_direct_submission_lm():
    store, names, apis, templates = _lm_setup()
    cap = max(2, store.num_pages() // 2)     # model switches must refault

    def make():
        server = WeightServer(store, cap, storage=StorageModel("dram"),
                              backend="device")
        return LMServingEngine(server, apis, templates, scheduler="fifo",
                               overlap=True)

    def payload(model, rid, rng):
        return rng.integers(1, 96, size=(1, 5)).astype(np.int32), 3

    gen = OpenLoopTraffic(names, rate=300.0, zipf_alpha=1.1, slo_s=1.0,
                          seed=9, payload_fn=payload)
    fe = ServingFrontend(make(), max_batch=3,
                         compute_model=BatchComputeModel())
    st = fe.run(gen.generate(18))
    assert st.shed_requests == 0 and len(fe.results) == 18

    engine2 = make()
    for model, kept in fe.dispatched:
        engine2.submit(model, np.concatenate(
            [np.asarray(r.payload[0]) for r in kept], axis=0), steps=3)
        engine2.run(max_batches=1)
        out = np.asarray(engine2.last_tokens)
        row = 0
        for r in kept:
            n = np.asarray(r.payload[0]).shape[0]
            np.testing.assert_array_equal(fe.results[r.rid],
                                          out[row: row + n])
            row += n


def test_lm_merge_rejects_mixed_decode_steps():
    store, names, apis, templates = _lm_setup()
    server = WeightServer(store, store.num_pages(),
                          storage=StorageModel("dram"), backend="device")
    engine = LMServingEngine(server, apis, templates, scheduler="fifo")
    fe = ServingFrontend(engine, max_batch=4)
    prompts = np.ones((1, 4), np.int32)
    reqs = [Request(0, names[0], (prompts, 3), 0.0, 1.0),
            Request(1, names[0], (prompts, 4), 0.0, 1.0)]
    with pytest.raises(ValueError, match="mixed decode steps"):
        fe._merge(reqs)
