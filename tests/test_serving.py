import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.data.pipeline import SyntheticTextTask
from repro.launch.serve import build_store
from repro.serving.engine import (EmbeddingServingEngine, StorageModel,
                                  WeightServer)
from repro.serving.kvcache import PagedKVCache


# ------------------------------------------------------------- kv cache ---
@given(st.lists(st.tuples(st.integers(1, 40), st.integers(0, 30)),
                min_size=1, max_size=20))
@settings(max_examples=40, deadline=None)
def test_kvcache_alloc_release_invariants(ops):
    cache = PagedKVCache(num_blocks=64, block_size=4)
    live = {}
    for i, (tokens, extend) in enumerate(ops):
        rid = f"r{i}"
        if not cache.can_allocate(tokens):
            # release the oldest to make room
            if live:
                old = next(iter(live))
                cache.release(old)
                del live[old]
            if not cache.can_allocate(tokens):
                continue
        t = cache.allocate(rid, tokens)
        live[rid] = t
        for _ in range(extend):
            try:
                cache.extend(rid)
            except MemoryError:
                break
    # invariant: no block owned twice, free+used == total
    owned = [b for t in cache.tables.values() for b in t.blocks]
    assert len(owned) == len(set(owned))
    assert len(owned) + len(cache.free) == 64


def test_kvcache_slot_mapping():
    cache = PagedKVCache(8, 4)
    cache.allocate("a", 6)
    s0 = cache.position_to_slot("a", 0)
    s5 = cache.position_to_slot("a", 5)
    assert s0 % 4 == 0
    assert s5 == cache.tables["a"].blocks[1] * 4 + 1


def test_kvcache_exhaustion():
    cache = PagedKVCache(2, 4)
    cache.allocate("a", 8)
    with pytest.raises(MemoryError):
        cache.allocate("b", 1)
    cache.release("a")
    cache.allocate("b", 8)


# ------------------------------------------------------- storage model ---
def test_storage_latency_ordering():
    nbytes = 1 << 20
    t = {k: StorageModel(k).fetch_seconds(nbytes)
         for k in ("hdd", "ssd", "nvme", "dram")}
    assert t["hdd"] > t["ssd"] > t["nvme"] > t["dram"]


def test_hedged_fetch_cuts_tail():
    slow = StorageModel("hdd", jitter=1.2, seed=0)
    hedged = StorageModel("hdd", jitter=1.2, hedge_after=0.02, seed=0)
    n = 400
    base = sorted(slow.fetch_seconds(1 << 20) for _ in range(n))
    cut = sorted(hedged.fetch_seconds(1 << 20) for _ in range(n))
    p99 = int(n * 0.99)
    assert cut[p99] <= base[p99]


# ------------------------------------------------------------ engine e2e ---
def test_embedding_engine_end_to_end():
    task = SyntheticTextTask(vocab=512, d=32, seed=0)
    store, heads = build_store(task, num_models=4, block_shape=(32, 32),
                               blocks_per_page=4)
    assert store.storage_bytes() < store.dense_bytes()
    server = WeightServer(store, capacity_pages=12,
                          policy="optimized_mru", storage=StorageModel("ssd"))
    engine = EmbeddingServingEngine(server, heads)
    correct = total = 0
    for v in range(4):
        name = f"word2vec-v{v}"
        docs, labels = task.sample(64, variant=v, seed=100 + v)
        engine.submit(name, docs)
    stats = engine.run()
    assert stats.batches == 4
    assert server.pool.hits + server.pool.misses > 0


def test_dedup_improves_hit_ratio_vs_dense():
    """The paper's core serving claim: with dedup, shared pages raise the
    cache hit ratio for a fixed pool size."""
    task = SyntheticTextTask(vocab=1024, d=32, seed=1)

    def run(pack):
        store, heads = build_store(task, num_models=5,
                                   block_shape=(32, 32), blocks_per_page=4,
                                   pack_strategy=pack)
        cap = 20
        server = WeightServer(store, cap, "optimized_mru",
                              StorageModel("ssd"))
        engine = EmbeddingServingEngine(server, heads)
        rng = np.random.default_rng(7)
        for b in range(30):
            v = int(rng.integers(0, 5))
            docs, _ = task.sample(16, variant=v, seed=500 + b)
            engine.submit(f"word2vec-v{v}", docs)
        engine.run()
        return server.pool.hit_ratio, store.num_pages()

    hr_dedup, pages_dedup = run("two_stage")
    hr_base, pages_base = run("dedup_base")
    assert pages_dedup <= pages_base
    assert hr_dedup >= hr_base - 0.02      # dedup never hurts materially


def test_model_accuracy_preserved_after_dedup():
    task = SyntheticTextTask(vocab=512, d=32, seed=2)
    store, heads = build_store(task, num_models=3, block_shape=(32, 32),
                               blocks_per_page=4)
    for v in range(3):
        name = f"word2vec-v{v}"
        emb_orig = task.variant_embedding(v)
        emb_dedup = store.materialize(name, "embedding")
        docs, labels = task.sample(256, variant=v, seed=900 + v)
        acc_orig = task.accuracy(emb_orig, heads[name], docs, labels)
        acc_dedup = task.accuracy(emb_dedup, heads[name], docs, labels)
        assert acc_orig - acc_dedup < 0.035   # paper's threshold t
