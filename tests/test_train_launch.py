"""Trainer integration: loss decreases, resume continues, elastic re-mesh
(host-count change) replays deterministic data."""
import numpy as np
import pytest

from repro.data.pipeline import token_batches
from repro.launch.train import main as train_main


@pytest.mark.slow
def test_loss_decreases_tiny_lm(tmp_path):
    # uniform-random token streams sit at the entropy floor (ln V), so the
    # optimizer smoke test overfits a fixed batch instead
    out = train_main(["--arch", "deepseek-7b", "--reduced", "--steps", "30",
                      "--batch", "8", "--seq", "32", "--lr", "3e-3",
                      "--seed", "1", "--overfit"])
    losses = out["losses"]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5


@pytest.mark.slow
def test_resume_continues(tmp_path):
    ck = str(tmp_path / "ck")
    train_main(["--arch", "mamba2-1.3b", "--reduced", "--steps", "6",
                "--batch", "4", "--seq", "16", "--ckpt", ck,
                "--ckpt-every", "3"])
    out = train_main(["--arch", "mamba2-1.3b", "--reduced", "--steps", "9",
                      "--batch", "4", "--seq", "16", "--ckpt", ck,
                      "--resume", "auto"])
    assert len(out["losses"]) == 3          # resumed at 6, ran 6..8


@pytest.mark.slow
def test_compressed_grads_track_uncompressed(tmp_path):
    a = train_main(["--arch", "deepseek-7b", "--reduced", "--steps", "10",
                    "--batch", "4", "--seq", "16", "--seed", "2"])
    b = train_main(["--arch", "deepseek-7b", "--reduced", "--steps", "10",
                    "--batch", "4", "--seq", "16", "--seed", "2",
                    "--compress-grads"])
    # int8+EF stays close to the fp32 trajectory
    assert abs(a["losses"][-1] - b["losses"][-1]) < 0.25


def test_data_shards_partition_batch():
    """Union of host shards == full batch content domain; disjoint streams
    per host (elastic re-mesh safety)."""
    full = next(token_batches(97, 8, 16, seed=3, host_index=0,
                              host_count=1))
    h0 = next(token_batches(97, 8, 16, seed=3, host_index=0, host_count=2))
    h1 = next(token_batches(97, 8, 16, seed=3, host_index=1, host_count=2))
    assert h0["tokens"].shape == (4, 16)
    assert h1["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])
    # determinism: regenerating the same (step, host) gives identical data
    h0b = next(token_batches(97, 8, 16, seed=3, host_index=0, host_count=2))
    assert np.array_equal(h0["tokens"], h0b["tokens"])
