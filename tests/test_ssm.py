"""SSD (mamba-2) numerics: chunked scan == naive recurrence == decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import ssd_chunked, ssd_decode

RNG = np.random.default_rng(0)


def _naive_ssd(xh, dt, A, Bm, Cm, Dp):
    B, S, H, hd = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    R = H // G
    state = np.zeros((B, H, hd, N), np.float64)
    ys = np.zeros((B, S, H, hd), np.float64)
    for t in range(S):
        dA = np.exp(dt[:, t] * A)                        # [B,H]
        Bh = np.repeat(Bm[:, t], R, axis=1)              # [B,H,N]
        Ch = np.repeat(Cm[:, t], R, axis=1)
        state = dA[:, :, None, None] * state \
            + dt[:, t][:, :, None, None] * xh[:, t][..., None] \
            * Bh[:, :, None, :]
        ys[:, t] = np.einsum("bhdn,bhn->bhd", state, Ch) \
            + Dp[None, :, None] * xh[:, t]
    return ys, state


@pytest.mark.parametrize("S,chunk,G", [(16, 4, 1), (24, 8, 2), (7, 16, 1)])
def test_chunked_matches_naive(S, chunk, G):
    B, H, hd, N = 2, 4, 8, 8
    xh = RNG.standard_normal((B, S, H, hd)).astype(np.float32)
    dt = (RNG.random((B, S, H)) * 0.1 + 0.01).astype(np.float32)
    A = -(RNG.random(H) * 0.5 + 0.1).astype(np.float32)
    Bm = RNG.standard_normal((B, S, G, N)).astype(np.float32)
    Cm = RNG.standard_normal((B, S, G, N)).astype(np.float32)
    Dp = RNG.random(H).astype(np.float32)
    y, state = ssd_chunked(jnp.asarray(xh), jnp.asarray(dt), jnp.asarray(A),
                           jnp.asarray(Bm), jnp.asarray(Cm), jnp.asarray(Dp),
                           chunk)
    yn, sn = _naive_ssd(xh, dt, A, Bm, Cm, Dp)
    np.testing.assert_allclose(np.asarray(y), yn, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(state), sn, rtol=1e-4, atol=1e-4)


def test_decode_continues_chunked():
    B, S, H, hd, N, G = 1, 12, 2, 4, 4, 1
    xh = RNG.standard_normal((B, S + 1, H, hd)).astype(np.float32)
    dt = (RNG.random((B, S + 1, H)) * 0.1 + 0.01).astype(np.float32)
    A = -(RNG.random(H) * 0.5 + 0.1).astype(np.float32)
    Bm = RNG.standard_normal((B, S + 1, G, N)).astype(np.float32)
    Cm = RNG.standard_normal((B, S + 1, G, N)).astype(np.float32)
    Dp = RNG.random(H).astype(np.float32)
    y_full, _ = ssd_chunked(*(jnp.asarray(a) for a in
                              (xh, dt, A, Bm, Cm, Dp)), 4)
    _, state = ssd_chunked(jnp.asarray(xh[:, :S]), jnp.asarray(dt[:, :S]),
                           jnp.asarray(A), jnp.asarray(Bm[:, :S]),
                           jnp.asarray(Cm[:, :S]), jnp.asarray(Dp), 4)
    y1, _ = ssd_decode(jnp.asarray(xh[:, S]), jnp.asarray(dt[:, S]),
                       jnp.asarray(A), jnp.asarray(Bm[:, S]),
                       jnp.asarray(Cm[:, S]), jnp.asarray(Dp), state)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y_full[:, S]),
                               rtol=1e-4, atol=1e-4)
