"""Scheduler / prefetcher / overlapped-timeline tests (deterministic).

The load-bearing scenario is two *disjoint* model groups (a0,a1 vs b0,b1):
variants within a group dedup onto the same pages, groups share nothing.
Interleaved traffic (a,b,a,b,...) makes round-robin thrash a pool sized
for one group, while dedup-affinity co-schedules sharers back-to-back.
"""
import numpy as np
import pytest

from repro.core import DedupConfig, LSHConfig, ModelStore, StoreConfig
from repro.core.lsh import estimate_r
from repro.core.blocks import block_tensor
from repro.serving import (DedupAffinityScheduler, EmbeddingServingEngine,
                           FetchComputeTimeline, FifoScheduler, Prefetcher,
                           RoundRobinScheduler, StorageModel, WeightServer,
                           make_scheduler)


def _two_group_store(d=64, rows=256, block=(32, 32), blocks_per_page=2):
    """Two bases far apart in L2; two variants per base differing on a few
    row blocks -> heavy intra-group page sharing, zero inter-group."""
    rng = np.random.default_rng(0)
    base_a = rng.standard_normal((rows, d)).astype(np.float32)
    base_b = (rng.standard_normal((rows, d)) + 8.0).astype(np.float32)
    blocks, _ = block_tensor(base_a, block)
    r = estimate_r(blocks, quantile=0.5)
    cfg = StoreConfig(
        dedup=DedupConfig(block_shape=block,
                          lsh=LSHConfig(num_bands=16, rows_per_band=4, r=r,
                                        collision_threshold=8),
                          validate=False),
        blocks_per_page=blocks_per_page)
    store = ModelStore(cfg)
    heads = {}
    hr = np.random.default_rng(1)
    for g, base in (("a", base_a), ("b", base_b)):
        for v in range(2):
            emb = base.copy()
            emb[v * 32:(v + 1) * 32] += 50.0 + v     # private row blocks
            name = f"{g}{v}"
            store.register(name, {"embedding": emb})
            heads[name] = hr.standard_normal((d, 8)).astype(np.float32)
    return store, heads


def _interleaved_trace(models, batches=24, doc_len=6, rows=256, seed=3):
    rng = np.random.default_rng(seed)
    out = []
    for b in range(batches):
        m = models[b % len(models)]
        docs = rng.integers(0, rows, size=(8, doc_len))
        out.append((m, docs))
    return out


def _run_engine(store, heads, trace, scheduler, capacity, overlap=False,
                prefetcher=False, storage="hdd", policy="optimized_mru"):
    server = WeightServer(store, capacity, policy, StorageModel(storage))
    engine = EmbeddingServingEngine(
        server, heads, scheduler=scheduler,
        prefetcher=Prefetcher(server) if prefetcher else None,
        overlap=overlap)
    for model, docs in trace:
        engine.submit(model, docs)
    stats = engine.run()
    return stats, server


# ------------------------------------------------------------ the big two ---
def test_dedup_affinity_beats_round_robin_hit_ratio():
    """On an interleaved shared-page trace with a pool sized for one model
    group, affinity scheduling must not lose to round-robin — and here it
    strictly wins, because co-scheduled sharers reuse resident pages."""
    store, heads = _two_group_store()
    # capacity: one group's working set fits, both don't
    group_pages = len(set(store.model_pages("a0"))
                      | set(store.model_pages("a1")))
    cap = max(2, group_pages)
    assert cap < store.num_pages()
    trace = _interleaved_trace(["a0", "b0", "a1", "b1"])

    _, srv_rr = _run_engine(store, heads, trace, "round_robin", cap)
    _, srv_aff = _run_engine(store, heads, trace, "dedup_affinity", cap)
    assert srv_aff.pool.hit_ratio >= srv_rr.pool.hit_ratio
    assert srv_aff.pool.hit_ratio > srv_rr.pool.hit_ratio + 0.05


def test_overlap_never_slower_than_serial():
    """Double-buffered fetch/compute must never report more end-to-end
    virtual time than the serial engine on the same trace."""
    store, heads = _two_group_store()
    cap = max(2, store.num_pages() // 2)
    trace = _interleaved_trace(["a0", "b0", "a1", "b1"])

    s_serial, _ = _run_engine(store, heads, trace, "round_robin", cap,
                              overlap=False)
    s_async, _ = _run_engine(store, heads, trace, "round_robin", cap,
                             overlap=True)
    # within-run invariant: the overlapped makespan never exceeds the
    # serial sum of its own channels
    assert s_async.makespan_seconds <= s_async.total_seconds + 1e-12
    # cross-run: same trace, same pool decisions; storage is hdd so the
    # (deterministic) virtual fetch time dwarfs wall-clock compute noise
    assert s_async.makespan_seconds < s_serial.makespan_seconds
    assert s_serial.makespan_seconds == pytest.approx(
        s_serial.total_seconds)


# ------------------------------------------------------------- schedulers ---
def test_fifo_preserves_arrival_order():
    s = FifoScheduler()
    for i, m in enumerate("abcab"):
        s.submit(m, i)
    assert [s.next_batch().payload for _ in range(5)] == [0, 1, 2, 3, 4]
    assert s.next_batch() is None


def test_round_robin_matches_legacy_sweep_order():
    s = RoundRobinScheduler()
    for i, m in enumerate(["a", "a", "b", "b", "c"]):
        s.submit(m, i)
    got = [(s.next_batch().model) for _ in range(5)]
    assert got == ["a", "b", "c", "a", "b"]


def test_affinity_prefers_resident_overlap_and_never_starves():
    s = DedupAffinityScheduler(max_defer=2)
    s.submit("a", 0, pages=[1, 2])
    s.submit("b", 1, pages=[8, 9])
    s.submit("a", 2, pages=[1, 3])
    s.submit("a", 3, pages=[2, 3])
    resident = {1, 2, 3}
    # a overlaps resident fully, b not at all
    assert s.next_batch(resident).model == "a"
    assert s.next_batch(resident).model == "a"
    # b deferred twice -> starvation bound forces it despite zero overlap
    assert s.next_batch(resident).model == "b"
    assert s.next_batch(resident).model == "a"
    assert s.next_batch(resident) is None


def test_affinity_starvation_bound_under_continuous_submission():
    """The max_defer bound must hold under *continuous* interleaved
    submission, not just a static queue: fresh perfectly-resident work
    arrives before every scheduling decision, so the cold batch would
    starve forever on score alone.  It must be forced after exactly
    max_defer deferrals, and once served the hot backlog resumes."""
    s = DedupAffinityScheduler(max_defer=3)
    resident = {1, 2}
    s.submit("b", "cold", pages=[50, 51])        # zero resident overlap
    order = []
    for i in range(8):
        s.submit("a", f"hot{i}", pages=[1, 2])   # fresh full-overlap work
        order.append(s.next_batch(resident).model)
    assert order[:3] == ["a"] * 3                # deferred while hot wins
    assert order[3] == "b"                       # forced at max_defer
    assert order[4:] == ["a"] * 4                # backlog drains after
    # the bound resets: a second cold batch waits max_defer again
    s.submit("b", "cold2", pages=[50, 51])
    order2 = []
    for i in range(8, 14):
        s.submit("a", f"hot{i}", pages=[1, 2])
        order2.append(s.next_batch(resident).model)
    assert order2.index("b") == 3


def test_make_scheduler_factory():
    assert isinstance(make_scheduler("fifo"), FifoScheduler)
    sched = RoundRobinScheduler()
    assert make_scheduler(sched) is sched
    with pytest.raises(ValueError):
        make_scheduler("nope")


# ------------------------------------------------------------- timeline ----
def test_timeline_double_buffer_math():
    tl = FetchComputeTimeline()
    issue, done = tl.advance(2.0, 3.0)        # fetch 0-2, compute 2-5
    assert (issue, done) == (0.0, 5.0)
    issue, done = tl.advance(1.0, 1.0)        # fetch 2-3 ∥ compute, c 5-6
    assert (issue, done) == (2.0, 6.0)
    assert tl.makespan == 6.0
    tl.charge_fetch(10.0)                     # prefetch occupies channel
    assert tl.fetch_clock == 13.0
    assert tl.makespan == 13.0


# ------------------------------------------------------------- prefetcher ---
def test_pool_prefetch_does_not_pollute_demand_stats():
    store, _ = _two_group_store()
    pool = store.make_buffer_pool(capacity_pages=store.num_pages())
    pages = store.model_pages("a0")
    assert pool.prefetch("a0", pages[0]) is True
    assert pool.prefetch("a0", pages[0]) is False      # already resident
    assert (pool.hits, pool.misses) == (0, 0)
    assert pool.prefetches == 1
    # a later demand access of the prefetched page is a HIT
    assert pool.access("a0", pages[0]) is True
    assert (pool.hits, pool.misses) == (1, 0)


def test_pool_prefetch_declines_hotter_victims():
    store, _ = _two_group_store()
    pool = store.make_buffer_pool(capacity_pages=2)
    hot = store.model_pages("a0")[:2]
    for p in hot:                       # demand-resident, hot model
        pool.access("a0", p)
        pool.access("a1", p)
    cold = [p for p in store.model_pages("b0") if p not in hot][0]
    # b0 has ~zero lambda: its page cannot displace the a-group's pages
    assert pool.prefetch("b0", cold) is False
    assert pool.prefetch_declined == 1
    assert set(hot) <= pool.resident_pages()


def test_prefetched_page_stays_most_evictable_under_mru():
    """An unused speculative page must be the policy's FIRST victim, even
    under MRU-family policies whose victims come from the MRU end."""
    store, _ = _two_group_store()
    pool = store.make_buffer_pool(capacity_pages=3, policy="mru")
    a = store.model_pages("a0")
    pool.access("a0", a[0])
    pool.access("a0", a[1])
    cold = store.model_pages("b0")[0]
    assert pool.prefetch("b0", cold) is True        # into the free slot
    pool.access("a0", a[2])                          # miss -> must evict
    assert cold not in pool.resident_pages()         # ...the unused page
    assert {a[0], a[1], a[2]} == pool.resident_pages()


def test_prefetcher_budget_respected():
    store, heads = _two_group_store()
    server = WeightServer(store, store.num_pages(), "optimized_mru",
                          StorageModel("hdd"))
    # warm lambda for a0 so the prefetcher has a hot model to target
    server.access_pages("a0", store.model_pages("a0")[:1])
    pf = Prefetcher(server, max_pages_per_step=64)
    t = pf.step(budget_s=0.0)
    assert t == 0.0 and pf.stats.issued == 0
    t = pf.step(budget_s=1.0)           # hdd: seek 8ms, room for many
    assert 0.0 < t <= 1.0
    assert pf.stats.issued > 0


def test_lambda_rates_exposed():
    store, _ = _two_group_store()
    pool = store.make_buffer_pool(capacity_pages=4)
    for p in store.model_pages("a0"):
        pool.access("a0", p)
    rates = pool.model_rates()
    assert rates.get("a0", 0.0) > 0.0


# -------------------------------------------------- queue-aware lookahead ---
def test_pending_batches_exposed_in_arrival_order():
    for sched in (FifoScheduler(), RoundRobinScheduler(),
                  DedupAffinityScheduler()):
        for i, m in enumerate(["a", "b", "a", "c"]):
            sched.submit(m, i, pages=[i])
        got = sched.pending_batches()
        assert [b.payload for b in got] == [0, 1, 2, 3]
        assert sched.pending() == 4                  # non-destructive
        sched.next_batch(set())
        assert len(sched.pending_batches()) == 3


def test_lookahead_plans_queued_pages_before_lambda():
    """Satellite: with queued batches visible, the prefetcher pulls THEIR
    pages first (deduped against residency), before any λ speculation."""
    store, heads = _two_group_store()
    server = WeightServer(store, store.num_pages(), "optimized_mru",
                          StorageModel("hdd"))
    # make b0 the λ-hottest model: pure speculation would pick b pages
    for p in store.model_pages("b0")[:3]:
        server.pool.access("b0", p)
    sched = FifoScheduler()
    a_pages = [p for p in store.model_pages("a0")
               if p not in server.pool.resident_pages()]
    sched.submit("a0", None, pages=a_pages,
                 pages_gen=store.pack_generation)
    pf = Prefetcher(server, max_pages_per_step=4)
    pf.attach_scheduler(sched)
    plan = pf.plan()
    assert plan, "nothing planned"
    planned_pages = [p for _, p in plan]
    assert set(planned_pages) <= set(a_pages)        # queue first, not λ
    # stale generation (simulated repack) falls back to λ speculation
    sched.pending_batches()[0].pages_gen = -1
    assert all(m == "b0" for m, _ in pf.plan())


def test_lookahead_hits_proven_end_to_end():
    """The proof stat: pages issued from the queue's page sets get
    demanded by the very batches that advertised them -> lookahead_hits
    > 0, and those demand accesses are pool hits."""
    store, heads = _two_group_store()
    cap = store.num_pages()
    # dram storage: wall compute dominates the virtual fetch clock, so
    # the fetch channel has idle headroom for the engine to grant as
    # prefetch budget (hdd would starve speculation entirely)
    server = WeightServer(store, cap, "optimized_mru", StorageModel("dram"))
    prefetcher = Prefetcher(server, max_pages_per_step=8)
    engine = EmbeddingServingEngine(server, heads, scheduler="fifo",
                                    prefetcher=prefetcher, overlap=True)
    assert prefetcher.scheduler is engine.scheduler   # auto-attached
    trace = _interleaved_trace(["a0", "b0", "a1", "b1"], batches=16)
    for model, docs in trace:
        engine.submit(model, docs)
    engine.run()
    assert prefetcher.stats.lookahead_issued > 0
    assert prefetcher.stats.lookahead_hits > 0
    assert prefetcher.stats.lookahead_hits <= prefetcher.stats.issued


def test_lookahead_beats_pure_lambda_on_cold_models():
    """A cold model's queued batch can't be predicted by λ rates; the
    queue-aware tier still prefetches it, so the cold batch sees hits
    where the pure-λ prefetcher sees misses."""
    store, heads = _two_group_store()
    cap = store.num_pages()
    trace = _interleaved_trace(["a0", "a1"], batches=10) \
        + _interleaved_trace(["b0"], batches=2, seed=9)

    def run(lookahead):
        server = WeightServer(store, cap, "optimized_mru",
                              StorageModel("dram"))
        pf = Prefetcher(server, max_pages_per_step=8,
                        lookahead=lookahead)
        engine = EmbeddingServingEngine(server, heads, scheduler="fifo",
                                        prefetcher=pf, overlap=True)
        for model, docs in trace:
            engine.submit(model, docs)
        engine.run()
        return server.pool.hit_ratio

    assert run(lookahead=16) >= run(lookahead=0)
