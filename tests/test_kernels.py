"""Per-kernel allclose sweeps: shapes x dtypes vs the ref.py oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _tol(dt):
    return 1e-4 if dt == "float32" else 6e-2


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("M,bk,bn,nkb,nnb,nd", [
    (32, 16, 16, 2, 2, 2),
    (64, 32, 64, 3, 2, 4),
    (100, 16, 128, 2, 3, 3),        # ragged M (pad path)
    (16, 64, 32, 1, 4, 1),          # single distinct block (full dedup)
])
def test_dedup_matmul_sweep(dtype, M, bk, bn, nkb, nnb, nd):
    x = RNG.standard_normal((M, nkb * bk)).astype(dtype)
    pool = RNG.standard_normal((nd, bk, bn)).astype(dtype)
    bmap = RNG.integers(0, nd, (nkb, nnb)).astype(np.int32)
    y = ops.dedup_matmul(jnp.asarray(x), jnp.asarray(pool),
                         jnp.asarray(bmap), bm=16)
    yr = ref.dedup_matmul(jnp.asarray(x), jnp.asarray(pool),
                          jnp.asarray(bmap))
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=_tol(dtype), atol=_tol(dtype))


def test_dedup_matmul_batched_lead_dims():
    x = RNG.standard_normal((2, 5, 32)).astype(np.float32)
    pool = RNG.standard_normal((3, 16, 16)).astype(np.float32)
    bmap = RNG.integers(0, 3, (2, 2)).astype(np.int32)
    y = ops.dedup_matmul(jnp.asarray(x), jnp.asarray(pool),
                         jnp.asarray(bmap), bm=8)
    assert y.shape == (2, 5, 32)


@pytest.mark.parametrize("V,bv,D,B", [(64, 8, 32, 7), (128, 16, 64, 33)])
def test_dedup_embedding_sweep(V, bv, D, B):
    pool = RNG.standard_normal((5, bv, D)).astype(np.float32)
    rbmap = RNG.integers(0, 5, (V // bv,)).astype(np.int32)
    ids = RNG.integers(0, V, (B,)).astype(np.int32)
    e = ops.dedup_embedding(jnp.asarray(ids), jnp.asarray(pool),
                            jnp.asarray(rbmap))
    expect = np.stack([pool[rbmap[i // bv]][i % bv] for i in ids])
    np.testing.assert_allclose(np.asarray(e), expect, rtol=1e-6)


@pytest.mark.parametrize("n,dim,nh,r", [
    (16, 64, 16, 2.0), (33, 100, 24, 4.0), (128, 512, 128, 1.0)])
def test_lsh_signature_sweep(n, dim, nh, r):
    blocks = RNG.standard_normal((n, dim)).astype(np.float32)
    proj = RNG.standard_normal((dim, nh)).astype(np.float32)
    bias = (RNG.random(nh) * r).astype(np.float32)
    s = ops.lsh_signature(jnp.asarray(blocks), jnp.asarray(proj),
                          jnp.asarray(bias), r=r)
    sr = ref.lsh_signature(jnp.asarray(blocks), jnp.asarray(proj),
                           jnp.asarray(bias), r)
    assert (np.asarray(s) == np.asarray(sr)).all()


@pytest.mark.parametrize("B,Sq,Skv,H,K,hd,causal,window,cap", [
    (2, 64, 64, 4, 2, 16, True, 0, 0.0),
    (1, 32, 48, 4, 4, 8, True, 16, 30.0),     # window + softcap
    (2, 16, 64, 2, 1, 16, False, 0, 0.0),     # cross attention
    (1, 48, 48, 8, 2, 32, True, 0, 50.0),     # GQA + softcap
])
def test_flash_attention_sweep(B, Sq, Skv, H, K, hd, causal, window, cap):
    q = RNG.standard_normal((B, Sq, H, hd)).astype(np.float32)
    k = RNG.standard_normal((B, Skv, K, hd)).astype(np.float32)
    v = RNG.standard_normal((B, Skv, K, hd)).astype(np.float32)
    o = ops.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            causal=causal, window=window, softcap=cap,
                            bq=16, bkv=16)
    orf = ref.flash_attention(jnp.asarray(q), jnp.asarray(k),
                              jnp.asarray(v), causal=causal, window=window,
                              softcap=cap)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                               rtol=1e-4, atol=1e-5)


def test_flash_matches_model_attention():
    """Pallas kernel vs the model-zoo chunked attention implementation."""
    from repro.models.attention import attend
    q = jnp.asarray(RNG.standard_normal((2, 32, 4, 16)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, 32, 2, 16)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, 32, 2, 16)), jnp.float32)
    o1 = ops.flash_attention(q, k, v, causal=True, bq=8, bkv=8)
    o2 = attend(q, k, v, causal=True, chunk=8)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-4, atol=1e-5)


def test_dedup_matmul_matches_store_virtual_tensor():
    """End-to-end: ModelStore virtual tensor -> kernel == dense matmul."""
    from repro.core import DedupConfig, LSHConfig, ModelStore, StoreConfig
    store = ModelStore(StoreConfig(
        dedup=DedupConfig(block_shape=(16, 16),
                          lsh=LSHConfig(num_bands=8, rows_per_band=2,
                                        r=8.0, collision_threshold=6),
                          validate=False),
        blocks_per_page=4))
    base = RNG.standard_normal((64, 32)).astype(np.float32)
    store.register("m0", {"w": base})
    store.register("m1", {"w": base + 1e-5})
    vt = store.virtual_tensor("m1", "w")
    pool = store.page_pool().reshape(-1, 16, 16)
    bmap = vt.block_map.reshape(vt.grid.grid)
    x = RNG.standard_normal((8, 64)).astype(np.float32)
    y = ops.dedup_matmul(jnp.asarray(x), jnp.asarray(pool),
                         jnp.asarray(bmap), bm=8)
    dense = store.materialize("m1", "w")
    np.testing.assert_allclose(np.asarray(y), x @ dense, rtol=1e-4,
                               atol=1e-4)
