#!/usr/bin/env python
"""Bench regression guard: compare freshly generated BENCH_serving.json /
BENCH_transfer.json / BENCH_faults.json / BENCH_traffic.json /
BENCH_recovery.json p50s against the baselines committed at HEAD.

Run by scripts/verify.sh AFTER the smoke benchmark rewrites the JSON
files in the working tree; the committed baseline is recovered with
``git show HEAD:<file>``.  Fails (exit 1) when:

  * a device-backend BENCH_serving p50 regresses past the tolerance
    against the committed baseline at the same capacity_frac, or
  * a grouped-transfer BENCH_transfer p50 regresses likewise, or
  * a fresh internal claim flag is False (grouped must beat per_page at
    every miss rate; device must not lose to numpy below capacity 1.0;
    chaos serving must stay bit-exact with bounded p99 and the naive
    no-recovery path must demonstrably die; the SLO-driven frontend
    must beat naive per-arrival dispatch on p99 — without losing
    goodput — at the highest traffic load rung).

Wall-clock p50s on shared CI runners are noisy, so the tolerance is
deliberately loose: fresh <= TOL * baseline + ABS_MS.  Comparisons are
skipped (with a notice) when the baseline is missing at HEAD or was
generated from a different scenario (smoke vs full / changed shapes) —
a guard that compares incomparable runs only trains people to delete it.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
TOL = 1.5         # multiplicative headroom on a baseline p50
ABS_MS = 0.5      # additive floor: ignore sub-noise absolute drift


def _fresh(name):
    """The working-tree JSON the smoke bench just wrote.  A missing,
    truncated or unparseable file is a clear FAIL message (the bench
    did not complete), never a stack trace."""
    path = os.path.join(REPO, name)
    if not os.path.exists(path):
        print(f"[bench-guard] FAIL: {name} was not generated")
        return None
    try:
        with open(path) as f:
            fresh = json.load(f)
    except (json.JSONDecodeError, OSError) as exc:
        print(f"[bench-guard] FAIL: {name} is unreadable or truncated "
              f"({type(exc).__name__}: {exc}) — the benchmark did not "
              "complete cleanly")
        return None
    if not isinstance(fresh, dict) or "configs" not in fresh:
        print(f"[bench-guard] FAIL: {name} has no 'configs' section — "
              "truncated or written by an incompatible benchmark version")
        return None
    return fresh


def _baseline(name):
    """The committed-at-HEAD JSON, or None with a skip notice.  Every
    failure mode — file absent at HEAD, git itself unavailable, a
    truncated or hand-mangled baseline — degrades to 'skip comparison',
    never a stack trace: the fresh run's internal claims still gate."""
    try:
        out = subprocess.run(["git", "show", f"HEAD:{name}"], cwd=REPO,
                             capture_output=True, text=True, check=True)
    except (subprocess.CalledProcessError, FileNotFoundError, OSError):
        print(f"[bench-guard] no committed baseline for {name}; "
              "skipping comparison (internal claims still checked)")
        return None
    try:
        base = json.loads(out.stdout)
    except json.JSONDecodeError:
        print(f"[bench-guard] baseline {name} at HEAD is truncated or "
              "unparseable; skipping comparison (internal claims still "
              "checked)")
        return None
    if not isinstance(base, dict):
        print(f"[bench-guard] baseline {name} at HEAD is not a JSON "
              "object; skipping comparison")
        return None
    return base


def _comparable(fresh, base, name):
    fs, bs = fresh.get("scenario", {}), (base or {}).get("scenario", {})
    if base is None:
        return False
    if not isinstance(base.get("configs"), list):
        print(f"[bench-guard] baseline {name} has no 'configs' list; "
              "skipping p50 comparison")
        return False
    if fs != bs:
        print(f"[bench-guard] {name}: scenario changed "
              "(smoke/full or shapes); skipping p50 comparison")
        return False
    return True


def _check_p50(name, label, fresh_ms, base_ms, failures):
    limit = TOL * base_ms + ABS_MS
    status = "ok" if fresh_ms <= limit else "REGRESSION"
    print(f"[bench-guard] {name} {label}: p50 {fresh_ms:.3f}ms "
          f"vs baseline {base_ms:.3f}ms (limit {limit:.3f}ms) {status}")
    if fresh_ms > limit:
        failures.append(f"{name} {label}")


def main() -> int:
    failures = []

    serving = _fresh("BENCH_serving.json")
    if serving is None:
        return 1
    # internal claim: device p50 <= numpy p50 whenever the pool is
    # smaller than the working set (the fig-8 regime).  The bench's own
    # boolean flag is zero-tolerance; these are wall-clock p50s on a
    # shared runner, so the guard re-derives the claim with the same
    # headroom as the baseline comparisons — a hard fail here should
    # mean the device path actually regressed, not that the runner
    # was busy.
    for c in serving["configs"]:
        if c["capacity_frac"] >= 1.0:
            continue
        dev, ref = c["device"]["p50_ms"], c["numpy"]["p50_ms"]
        if dev > TOL * ref + ABS_MS:
            failures.append(
                f"BENCH_serving device p50 {dev:.3f}ms lost to numpy "
                f"{ref:.3f}ms at frac={c['capacity_frac']}")
    base = _baseline("BENCH_serving.json")
    if _comparable(serving, base, "BENCH_serving.json"):
        by_frac = {c["capacity_frac"]: c for c in base["configs"]}
        for c in serving["configs"]:
            b = by_frac.get(c["capacity_frac"])
            if b is None:
                continue
            _check_p50("BENCH_serving", f"device@frac={c['capacity_frac']}",
                       c["device"]["p50_ms"], b["device"]["p50_ms"],
                       failures)

    transfer = _fresh("BENCH_transfer.json")
    if transfer is None:
        return 1
    for c in transfer["configs"]:
        # wall-clock claim gets the noise headroom; the fetch-channel
        # claim is a deterministic virtual clock and stays exact
        g, pp = c["grouped"]["p50_ms"], c["per_page"]["p50_ms"]
        if g > TOL * pp + ABS_MS:
            failures.append(
                f"BENCH_transfer grouped p50 {g:.3f}ms lost to per_page "
                f"{pp:.3f}ms at frac={c['capacity_frac']}")
        if not c["grouped_le_per_page_fetch_p50"]:
            failures.append(
                f"BENCH_transfer grouped fetch p50 lost to per_page at "
                f"frac={c['capacity_frac']}")
    if not transfer["gap_widens_as_capacity_shrinks"]:
        failures.append("BENCH_transfer: grouped-vs-per_page gap did not "
                        "widen as capacity shrank (deterministic fetch "
                        "channel)")
    base = _baseline("BENCH_transfer.json")
    if _comparable(transfer, base, "BENCH_transfer.json"):
        by_frac = {c["capacity_frac"]: c for c in base["configs"]}
        for c in transfer["configs"]:
            b = by_frac.get(c["capacity_frac"])
            if b is None:
                continue
            _check_p50("BENCH_transfer",
                       f"grouped@frac={c['capacity_frac']}",
                       c["grouped"]["p50_ms"], b["grouped"]["p50_ms"],
                       failures)

    faults = _fresh("BENCH_faults.json")
    if faults is None:
        return 1
    # Internal chaos claims are zero-tolerance: bit-exactness and the
    # naive-path-dies proof are determinism properties, not wall-clock
    # measurements — there is no runner-noise excuse for losing them.
    if not faults.get("logits_exact_all", False):
        failures.append("BENCH_faults: recovered serving was not "
                        "bit-exact across fault rates")
    if not faults.get("naive_path_dies", False):
        failures.append("BENCH_faults: the no-recovery path survived "
                        "bit-exact — injection is not load-bearing")
    if not faults.get("p99_bounded", False):
        failures.append("BENCH_faults: p99 under faults exceeded "
                        f"{faults.get('p99_factor_limit')}x the "
                        "fault-free p99 + grace (retry storm?)")
    base = _baseline("BENCH_faults.json")
    if _comparable(faults, base, "BENCH_faults.json"):
        by_rate = {c.get("rate"): c for c in base["configs"]}
        for c in faults["configs"]:
            b = by_rate.get(c.get("rate"))
            if b is None or "p50_ms" not in b:
                continue
            _check_p50("BENCH_faults", f"rate={c['rate']}",
                       c["p50_ms"], b["p50_ms"], failures)

    traffic = _fresh("BENCH_traffic.json")
    if traffic is None:
        return 1
    # The traffic bench runs entirely on the virtual clock (modeled
    # fetch + modeled compute), so both claims are deterministic under
    # the fixed seed — zero tolerance, same as the chaos claims.
    if not traffic.get("slo_beats_naive_p99_at_peak", False):
        failures.append("BENCH_traffic: SLO-aware formation/admission "
                        "did not beat naive per-arrival dispatch on p99 "
                        "at the highest load rung")
    if not traffic.get("slo_goodput_no_worse_at_peak", False):
        failures.append("BENCH_traffic: SLO-aware goodput lost to naive "
                        "dispatch at the highest load rung (shedding is "
                        "discarding servable requests)")
    base = _baseline("BENCH_traffic.json")
    if _comparable(traffic, base, "BENCH_traffic.json"):
        by_load = {c.get("load_frac"): c for c in base["configs"]}
        for c in traffic["configs"]:
            b = by_load.get(c.get("load_frac"))
            if b is None or b.get("slo", {}).get("p50_ms") is None:
                continue
            if c["slo"]["p50_ms"] is None:
                failures.append(
                    f"BENCH_traffic load={c['load_frac']}: frontend "
                    "served zero requests where the baseline served "
                    "some")
                continue
            _check_p50("BENCH_traffic", f"slo@load={c['load_frac']}",
                       c["slo"]["p50_ms"], b["slo"]["p50_ms"], failures)

    recovery = _fresh("BENCH_recovery.json")
    if recovery is None:
        return 1
    # Recovery claims are zero-tolerance: ledger balance, bit-exact
    # restart logits and exact wreckage counts are determinism
    # properties (virtual clock + content addressing), not wall-clock
    # measurements.
    for claim, msg in (
            ("recovery_counts_exact",
             "journal replay deleted the wrong number of orphans/temps"),
            ("restart_ledger_conserved",
             "the at-most-once request ledger did not balance after "
             "the warm restart"),
            ("restart_no_duplicates",
             "a request was served both before and after the restart"),
            ("restart_logits_exact",
             "pre+post-restart logits were not bit-exact against the "
             "uninterrupted run"),
            ("restart_did_work",
             "the restart scenario re-admitted nothing — the kill "
             "landed after the stream drained and proves nothing"),
            ("store_recovery_clean",
             "the serving store was dirty (journal/temps) at reopen"),
            ("restart_p99_bounded",
             "restarted-run p99 exceeded "
             f"{recovery.get('restart_p99_factor_limit')}x the "
             "uninterrupted p99")):
        if not recovery.get(claim, False):
            failures.append(f"BENCH_recovery: {msg}")
    base = _baseline("BENCH_recovery.json")
    if _comparable(recovery, base, "BENCH_recovery.json"):
        by_len = {c.get("journal_len"): c for c in base["configs"]}
        for c in recovery["configs"]:
            b = by_len.get(c.get("journal_len"))
            if b is None or "recover_ms" not in b:
                continue
            # recover_ms is wall time on a shared runner: same loose
            # tolerance as every other wall-clock comparison here
            _check_p50("BENCH_recovery", f"journal={c['journal_len']}",
                       c["recover_ms"], b["recover_ms"], failures)

    if failures:
        print("[bench-guard] FAILURES:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("[bench-guard] all checks passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
