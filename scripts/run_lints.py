#!/usr/bin/env python
"""Run the repo's contract lints (and ruff, when installed) over src/.

Exit status is non-zero on any finding, so `make lint`, verify.sh and
the CI lint job all hard-fail on a contract violation.  The custom
passes are stdlib-only (`repro.analysis.lint` imports no heavy deps),
so this runs in a bare container before anything is installed; ruff is
an optional extra — absent, it is skipped with a notice rather than
failing the build.
"""
import argparse
import shutil
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis.lint import run_lint          # noqa: E402
from repro.analysis.passes import default_passes  # noqa: E402

RUFF_PIN = "ruff==0.12.5"                         # match pyproject [dev]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files/dirs to lint (default: src)")
    ap.add_argument("--no-ruff", action="store_true",
                    help="run only the custom contract passes")
    ap.add_argument("--list-passes", action="store_true",
                    help="list registered passes and exit")
    args = ap.parse_args(argv)

    passes = default_passes()
    if args.list_passes:
        for p in passes:
            print(f"{p.name:<16} {p.description}")
        return 0

    paths = [str(ROOT / p) if not Path(p).is_absolute()
             and not Path(p).exists() else p for p in args.paths]

    findings = run_lint(paths, passes)
    for f in findings:
        print(f)
    rc = 1 if findings else 0
    print(f"contract lints: {len(findings)} finding(s) over "
          f"{len(paths)} path(s) [{', '.join(p.name for p in passes)}]")

    if not args.no_ruff:
        ruff = shutil.which("ruff")
        if ruff:
            proc = subprocess.run([ruff, "check", *paths], cwd=ROOT)
            fmt = subprocess.run([ruff, "format", "--check", *paths],
                                 cwd=ROOT)
            if proc.returncode or fmt.returncode:
                rc = 1
        else:
            print(f"ruff not installed; skipping (pip install {RUFF_PIN})")
    return rc


if __name__ == "__main__":
    sys.exit(main())
