#!/usr/bin/env bash
# Tier-1 verification: the ROADMAP.md command, from any cwd, followed by
# the serving-backend smoke benchmark (emits BENCH_serving.json,
# BENCH_storage.json and BENCH_sharding.json so the numpy-vs-device,
# local-vs-sqlite-vs-objsim and shard-count/placement perf trajectories
# are tracked from every verify run).
set -euo pipefail
cd "$(dirname "$0")/.."
# Contract lints first (repro.analysis passes; ruff rides along when
# installed): they are fast and fail with pinpointed path:line findings,
# so a protocol violation surfaces before the test matrix spins up.
python scripts/run_lints.py
# The pytest run includes the storage-backend round-trip matrix
# (tests/test_storage_backends.py: file/sqlite/objsim x fp32/fp16/bf16,
# orphan pruning, interrupted-commit crash safety, two-writer optimistic
# locking) and the sharded-serving suite (tests/test_shard_pool.py:
# placement invariants, 1/2/4-shard logit equivalence, borrow protocol).
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_serving_backends --smoke
# Chaos benchmark: serve identical traffic at 0/5/10% storage fault
# rates through the recovery layer (bit-exact logits, bounded p99) and
# prove the naive no-recovery path dies -> BENCH_faults.json.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_faults --smoke
# Open-loop traffic benchmark: SLO-driven frontend vs naive per-arrival
# dispatch across a 3-rung load sweep on the virtual clock
# -> BENCH_traffic.json (p99 + goodput claims at the peak rung).
# --trace additionally records the peak-rung SLO pass with the
# clock-bound tracer (BENCH_traffic.json is byte-identical either way)
# -> BENCH_traffic_trace.json, and trace_report.py re-proves the exact
# identities (queue+service==latency per request, per-channel span
# seconds == the VirtualClock ledger) from the file alone, exiting
# non-zero on any failure (DESIGN.md §10; `make trace-smoke` alone).
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_traffic --smoke --trace
python scripts/trace_report.py BENCH_traffic_trace.json
# Crash-recovery benchmark: journal replay cost vs wreckage size, plus
# the kill-and-warm-restart run (at-most-once ledger, bit-exact union
# of pre-/post-restart logits, bounded restart p99) ->
# BENCH_recovery.json (DESIGN.md §11; `make crash-sweep` runs the full
# kill-at-every-seam subprocess sweep separately).
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_recovery --smoke
# Bench regression guard: fresh BENCH_serving/BENCH_transfer p50s must
# stay within tolerance of the baselines committed at HEAD (and the
# grouped-transfer / device-vs-numpy / faults-recovery /
# traffic-frontend claims must hold); see
# scripts/check_bench_regression.py.
python scripts/check_bench_regression.py
