#!/usr/bin/env bash
# Tier-1 verification: the ROADMAP.md command, from any cwd, followed by
# the storage-backend round-trip matrix (file/sqlite/objsim x dtypes) and
# the serving-backend smoke benchmark (emits BENCH_serving.json and
# BENCH_storage.json so the numpy-vs-device and local-vs-sqlite-vs-objsim
# perf trajectories are tracked from every verify run).
set -euo pipefail
cd "$(dirname "$0")/.."
# The pytest run includes the storage-backend round-trip matrix
# (tests/test_storage_backends.py: file/sqlite/objsim x fp32/fp16/bf16,
# orphan pruning, interrupted-commit crash safety).
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_serving_backends --smoke
