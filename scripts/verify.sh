#!/usr/bin/env bash
# Tier-1 verification: the ROADMAP.md command, from any cwd, followed by
# the serving-backend smoke benchmark (emits BENCH_serving.json so the
# numpy-vs-device perf trajectory is tracked from every verify run).
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_serving_backends --smoke
