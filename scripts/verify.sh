#!/usr/bin/env bash
# Tier-1 verification: the ROADMAP.md command, from any cwd.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -x -q "$@"
