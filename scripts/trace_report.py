#!/usr/bin/env python
"""Per-request critical-path report over a trace written by --trace.

Reads a Chrome-trace (.json) or flat JSONL (.jsonl) trace from
``launch/serve.py --trace`` / ``benchmarks/bench_traffic.py --trace``
and prints:

  * a per-request stage attribution table: queue / fetch / compute at
    p50 and p99 (nearest-rank, matching ``ServeStats.percentile``),
  * the critical-path breakdown of the p99-latency request — its stage
    sum is checked EXACTLY equal to its reported latency (the spans
    carry residual-split stage times, so float addition cannot leak),
  * the channel-conservation proof re-verified from the file alone:
    per-channel charged-span seconds == the clock's channel ledger.

Exit status is non-zero when any exact identity fails, so
``make trace-smoke`` hard-fails on a tracer regression.
"""
import argparse
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.obs.export import load_trace           # noqa: E402

STAGES = ("queue_s", "fetch_s", "compute_s")


def _nearest_rank(xs, q):
    """Nearest-rank percentile over a non-empty sorted copy."""
    xs = sorted(xs)
    idx = max(0, min(len(xs) - 1, int(round(q / 100.0 * len(xs))) - 1))
    return xs[idx]


def _requests(spans):
    """The served (non-shed) request spans, as attr dicts + names."""
    out = []
    for sp in spans:
        if sp.get("kind") != "request":
            continue
        at = sp.get("attrs", {})
        if at.get("shed"):
            continue
        out.append(at)
    return out


def check_request_identities(reqs) -> list:
    """The residual-split stage identities, exact per request:
    queue+service == latency and fetch+compute == service.  Returns
    human-readable problem strings (empty = all exact)."""
    problems = []
    for at in reqs:
        rid = at.get("rid")
        q, s = at.get("queue_s"), at.get("service_s")
        f, c = at.get("fetch_s"), at.get("compute_s")
        lat = at.get("latency_s")
        if None in (q, s, f, c, lat):
            problems.append(f"rid={rid}: missing stage attrs")
            continue
        if q + s != lat:
            problems.append(
                f"rid={rid}: queue_s+service_s != latency_s "
                f"({q!r} + {s!r} != {lat!r})")
        if f + c != s:
            problems.append(
                f"rid={rid}: fetch_s+compute_s != service_s "
                f"({f!r} + {c!r} != {s!r})")
    return problems


def check_conservation(other) -> list:
    """Per-channel charged-span seconds vs the clock ledger, exact.
    ``other`` is the Chrome export's ``otherData`` (JSONL traces carry
    no ledger — the caller skips this check)."""
    problems = []
    span_ch = other.get("tracer_channel_seconds", {})
    clock_ch = other.get("clock_channels")
    if clock_ch is None:
        return problems
    for ch, booked in span_ch.items():
        spent = clock_ch.get(ch)
        if spent is None:
            problems.append(f"channel {ch!r}: charged in spans, "
                            "absent from the clock ledger")
        elif booked != spent:
            problems.append(f"channel {ch!r}: span time {booked!r} != "
                            f"clock spent {spent!r}")
    return problems


def attribution_table(reqs) -> str:
    lats = [at["latency_s"] for at in reqs]
    lines = ["stage        p50_ms      p99_ms    mean_ms"]
    for key in STAGES + ("latency_s",):
        xs = [at[key] for at in reqs]
        lines.append(f"{key.removesuffix('_s'):<10} "
                     f"{_nearest_rank(xs, 50) * 1e3:>9.3f}ms "
                     f"{_nearest_rank(xs, 99) * 1e3:>9.3f}ms "
                     f"{sum(xs) / len(xs) * 1e3:>8.3f}ms")
    p99 = _nearest_rank(lats, 99)
    worst = next(at for at in reqs if at["latency_s"] == p99)
    lines.append("")
    lines.append(f"p99 critical path (rid={worst.get('rid')}, "
                 f"model={worst.get('model')}):")
    for key in STAGES:
        frac = worst[key] / p99 if p99 else 0.0
        lines.append(f"  {key.removesuffix('_s'):<9} "
                     f"{worst[key] * 1e3:>9.3f}ms  {frac:>6.1%}")
    lines.append(f"  {'total':<9} {p99 * 1e3:>9.3f}ms  "
                 f"(== latency: "
                 f"{(worst['queue_s'] + worst['service_s']) == p99})")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="trace file (.json Chrome form with "
                                  "otherData, or flat .jsonl)")
    args = ap.parse_args(argv)

    spans = load_trace(args.trace)
    reqs = _requests(spans)
    print(f"# {args.trace}: {len(spans)} spans, "
          f"{len(reqs)} served requests")
    if not reqs:
        print("no request spans; nothing to attribute")
        return 0

    problems = check_request_identities(reqs)

    if str(args.trace).endswith(".jsonl"):
        print("# (.jsonl trace: no otherData ledger; conservation "
              "check skipped)")
    else:
        import json
        with open(args.trace) as fh:
            other = json.load(fh).get("otherData", {})
        problems += check_conservation(other)
        dropped = other.get("dropped_spans", 0)
        if dropped:
            print(f"# WARNING: ring dropped {dropped} spans; "
                  "attribution covers the retained tail only")

    print(attribution_table(reqs))

    slo = sum(1 for at in reqs if at.get("slo_miss"))
    print(f"\nrequests={len(reqs)} slo_misses={slo}")

    if problems:
        print(f"\n{len(problems)} exact-identity FAILURES:",
              file=sys.stderr)
        for p in problems:
            print(f"  {p}", file=sys.stderr)
        return 1
    print("exact identities OK: queue+service==latency, "
          "fetch+compute==service, span channels == clock ledger")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
