.PHONY: verify test-fast bench example

# Tier-1 verification (ROADMAP.md)
verify:
	./scripts/verify.sh

# Everything except the slow subprocess/dry-run tests
test-fast:
	./scripts/verify.sh -m "not slow"

bench:
	PYTHONPATH=src python -m benchmarks.run

example:
	PYTHONPATH=src python examples/multi_model_serving.py
