.PHONY: verify test-fast lint sanitize bench bench-smoke example

# Tier-1 verification (ROADMAP.md)
verify:
	./scripts/verify.sh

# Contract lints (repro.analysis passes) + ruff when installed
lint:
	python scripts/run_lints.py

# Full fast suite with the page-pool sanitizer armed (DESIGN.md §7)
sanitize:
	REPRO_SANITIZE=1 PYTHONPATH=src python -m pytest -q -m "not slow"

# Everything except the slow subprocess/dry-run tests
test-fast:
	./scripts/verify.sh -m "not slow"

bench:
	PYTHONPATH=src python -m benchmarks.run

# Fast numpy-vs-device serving comparison -> BENCH_serving.json, plus the
# storage-backend axis (local vs sqlite vs objsim) -> BENCH_storage.json
# and the shard-count x placement axis -> BENCH_sharding.json
# (run by scripts/verify.sh so the perf trajectories are tracked per PR)
bench-smoke:
	PYTHONPATH=src python -m benchmarks.bench_serving_backends --smoke

example:
	PYTHONPATH=src python examples/multi_model_serving.py
