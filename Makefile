.PHONY: verify test-fast lint sanitize bench bench-smoke bench-faults \
	chaos trace-smoke crash-sweep example

# Tier-1 verification (ROADMAP.md)
verify:
	./scripts/verify.sh

# Contract lints (repro.analysis passes) + ruff when installed
lint:
	python scripts/run_lints.py

# Full fast suite with the page-pool sanitizer armed (DESIGN.md §7)
sanitize:
	REPRO_SANITIZE=1 PYTHONPATH=src python -m pytest -q -m "not slow"

# Fast suite under seeded storage-fault injection (REPRO_FAULTS wraps
# every URL-opened backend) with the sanitizer armed: every grouped
# load that survives a fault must leave the pool consistent
chaos:
	REPRO_FAULTS="transient=0.05,corrupt=0.03,lock=0.05,torn=0.05,seed=13" \
	REPRO_SANITIZE=1 PYTHONPATH=src python -m pytest -q -m "not slow"

# Everything except the slow subprocess/dry-run tests
test-fast:
	./scripts/verify.sh -m "not slow"

bench:
	PYTHONPATH=src python -m benchmarks.run

# Fast numpy-vs-device serving comparison -> BENCH_serving.json, plus the
# storage-backend axis (local vs sqlite vs objsim) -> BENCH_storage.json
# and the shard-count x placement axis -> BENCH_sharding.json
# (run by scripts/verify.sh so the perf trajectories are tracked per PR)
bench-smoke:
	PYTHONPATH=src python -m benchmarks.bench_serving_backends --smoke
	PYTHONPATH=src python -m benchmarks.bench_faults --smoke
	PYTHONPATH=src python -m benchmarks.bench_traffic --smoke
	PYTHONPATH=src python -m benchmarks.bench_recovery --smoke

# Chaos benchmark alone: fault-rate ladder + naive-path-dies proof
# -> BENCH_faults.json (DESIGN.md §8)
bench-faults:
	PYTHONPATH=src python -m benchmarks.bench_faults --smoke

# Traffic bench with the clock-bound tracer on (BENCH_traffic.json is
# byte-identical either way) -> BENCH_traffic_trace.json, then the
# critical-path report, which exits non-zero if any exact identity
# (queue+service==latency, span channels == clock ledger) fails
# (DESIGN.md §10)
trace-smoke:
	PYTHONPATH=src python -m benchmarks.bench_traffic --smoke --trace
	python scripts/trace_report.py BENCH_traffic_trace.json

# Exhaustive kill-at-every-seam durability sweep: one subprocess per
# (crash point, backend kind), SIGKILLed mid-mutation, then recovered
# and invariant-checked (manifest readable, zero orphans, zero temps,
# empty journal, bit-exact logits).  A registered seam no scenario
# reaches fails the sweep (DESIGN.md §11)
crash-sweep:
	PYTHONPATH=src python -m repro.storage.crashpoints --sweep

example:
	PYTHONPATH=src python examples/multi_model_serving.py
