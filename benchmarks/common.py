"""Shared scenario builders + timing/CSV helpers for the benchmark suite.

Every module exposes ``run() -> List[Row]`` where a Row is
``(name, us_per_call, derived)`` — ``derived`` carries the paper-table
quantity (reduction factor, hit ratio, page count, accuracy drop, ...).
"""
from __future__ import annotations

import os
import sys
import time
from typing import Callable, List, Tuple

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (DedupConfig, LSHConfig, ModelStore,  # noqa: E402
                        StoreConfig)
from repro.core.blocks import block_tensor                    # noqa: E402
from repro.core.lsh import estimate_r                         # noqa: E402
from repro.data.pipeline import SyntheticTextTask             # noqa: E402

Row = Tuple[str, float, str]


def timed(fn: Callable, repeats: int = 3) -> Tuple[float, object]:
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6, out


def store_config(task_embed: np.ndarray, block_shape=(64, 64),
                 blocks_per_page=8, threshold=8, validate=False,
                 r_quantile=0.5, pack="two_stage",
                 drop_t=0.035, k=16) -> StoreConfig:
    blocks, _ = block_tensor(task_embed, block_shape)
    r = estimate_r(blocks, quantile=r_quantile)
    return StoreConfig(
        dedup=DedupConfig(block_shape=block_shape,
                          lsh=LSHConfig(num_bands=16, rows_per_band=4, r=r,
                                        collision_threshold=threshold),
                          validate=validate, validate_every_k=k,
                          accuracy_drop_threshold=drop_t),
        blocks_per_page=blocks_per_page, pack_strategy=pack)


def word2vec_scenario(num_models=6, vocab=2048, d=64, seed=0,
                      **cfg_kw):
    """Paper Sec. 7.1.1: N embedding variants fine-tuned from one base."""
    task = SyntheticTextTask(vocab=vocab, d=d, seed=seed)
    cfg = store_config(task.base_embed, **cfg_kw)
    store = ModelStore(cfg)
    heads, models = {}, {}
    for v in range(num_models):
        name = f"w2v-v{v}"
        emb = task.variant_embedding(v)
        models[name] = emb
        store.register(name, {"embedding": emb})
        heads[name] = task.train_head(emb, variant=v)
    return task, store, heads, models


def classification_scenario(num_models=5, vocab=2048, d=64, seed=0,
                            validate=True, **cfg_kw):
    """Paper Sec. 7.1.2: five text classifiers; variants 0/2 freeze the
    embedding (non-trainable, = base), 1/3/4 fine-tune it."""
    task = SyntheticTextTask(vocab=vocab, d=d, seed=seed)
    cfg = store_config(task.base_embed, validate=validate, **cfg_kw)
    store = ModelStore(cfg)
    rows = {}
    for v in range(num_models):
        name = f"clf-{v + 1}"
        emb = task.base_embed if v in (0, 2) else task.variant_embedding(v)
        head = task.train_head(emb, variant=v)
        docs, labels = task.sample(256, variant=v, seed=seed + 51 + v)
        acc0 = task.accuracy(emb, head, docs, labels)

        def ev(tensors, head=head, docs=docs, labels=labels):
            return task.accuracy(tensors["embedding"], head, docs, labels)

        store.register(name, {"embedding": emb},
                       evaluator=ev if validate else None)
        acc1 = ev({"embedding": store.materialize(name, "embedding")})
        rows[name] = {"emb": emb, "head": head, "docs": docs,
                      "labels": labels, "acc_before": acc0,
                      "acc_after": acc1}
    return task, store, rows


def ffnn_scenario(num_models=3, features=2048, hidden=256, labels=512,
                  seed=0, blocks_per_page=8):
    """Paper Sec. 7.1.3: transfer-learning FFNNs sharing W1 exactly."""
    rng = np.random.default_rng(seed)
    W1 = (rng.standard_normal((features, hidden)) * 0.05).astype(np.float32)
    cfg = store_config(W1, block_shape=(64, 64),
                       blocks_per_page=blocks_per_page, threshold=14)
    store = ModelStore(cfg)
    models = {}
    for v in range(num_models):
        W2 = (rng.standard_normal((hidden, labels)) * 0.05
              ).astype(np.float32)
        b1 = np.zeros(hidden, np.float32)
        b2 = np.zeros(labels, np.float32)
        name = f"ffnn-{v}"
        models[name] = {"W1": W1, "W2": W2, "b1": b1, "b2": b2}
        store.register(name, dict(models[name]))
    return store, models


def emit(rows: List[Row]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
