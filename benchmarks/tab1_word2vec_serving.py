"""Tab. 1 / Figs. 8-9 analog: multi-word2vec serving latency, dedup store
vs dense per-model store, across pool sizes and storage tiers."""
from __future__ import annotations

import numpy as np

from .common import Row, timed, word2vec_scenario
from repro.serving.engine import (EmbeddingServingEngine, StorageModel,
                                  WeightServer)


def _serve(store, heads, task, capacity_pages, storage, batches=40,
           seed=0, policy="optimized_mru"):
    server = WeightServer(store, capacity_pages, policy,
                          StorageModel(storage))
    engine = EmbeddingServingEngine(server, heads)
    rng = np.random.default_rng(seed)
    n = len(heads)
    for b in range(batches):
        v = int(rng.integers(0, n))
        docs, _ = task.sample(32, variant=v, seed=seed + 100 + b)
        engine.submit(f"w2v-v{v}", docs)
    stats = engine.run()
    return stats, server


def run() -> list:
    rows: list[Row] = []
    for num_models in (3, 6, 12):
        task, store, heads, _ = word2vec_scenario(num_models=num_models)
        red = store.dense_bytes() / max(1, store.storage_bytes())
        rows.append((f"tab1/storage_reduction/m{num_models}", 0.0,
                     f"{red:.2f}x"))
        # dense baseline: no dedup (threshold > bands -> nothing matches)
        from .common import store_config
        from repro.core import ModelStore
        base_cfg = store_config(task.base_embed, threshold=17)
        dense = ModelStore(base_cfg)
        for name in heads:
            v = int(name.split("v")[-1])
            dense.register(name, {"embedding": task.variant_embedding(v)})

        for storage in ("ssd", "hdd"):
            # memory-capped pool: half the dedup pages fit (paper: buffer
            # pool = half of available RAM); same absolute cap for both.
            cap = max(2, store.num_pages() // 2)
            stats, server = _serve(store, heads, task, cap, storage)
            stats_d, server_d = _serve(dense, heads, task, cap, storage)
            # latency = virtual storage I/O per batch (compute identical)
            us = stats.fetch_seconds / max(1, stats.batches) * 1e6
            us_d = stats_d.fetch_seconds / max(1, stats_d.batches) * 1e6
            rows.append((f"tab1/dedup/m{num_models}/{storage}", us,
                         f"hit={server.pool.hit_ratio:.3f}"))
            rows.append((f"tab1/dense/m{num_models}/{storage}", us_d,
                         f"hit={server_d.pool.hit_ratio:.3f};"
                         f"dedup_io_speedup={us_d / max(1e-9, us):.2f}x"))
    return rows
