"""Tab. 7 analog: page-packing strategies (DedupBase / Two-Stage /
Greedy-1 / Greedy-2) on the paper's scenario shapes, pages + pack time."""
from __future__ import annotations

import numpy as np

from .common import Row, timed, word2vec_scenario, classification_scenario
from repro.core.pagepack import (check_coverage, pack_dedup_base,
                                 pack_greedy1, pack_greedy2, pack_two_stage)


def _compare(tag, store, l):
    sets = store.dedup.tensor_sets()
    seqs = {(m, t): store.dedup.models[m].tensors[t].block_map
            for m in store.dedup.models
            for t in store.dedup.models[m].tensors}
    rows = []
    for name, fn in [("dedup_base", lambda: pack_dedup_base(seqs, l)),
                     ("two_stage", lambda: pack_two_stage(sets, l)),
                     ("greedy1", lambda: pack_greedy1(sets, l)),
                     ("greedy2", lambda: pack_greedy2(sets, l))]:
        us, res = timed(fn, repeats=2)
        check_coverage(res, sets, l)
        rows.append((f"tab7/{tag}/{name}", us,
                     f"pages={res.num_pages}"))
    return rows


def run() -> list:
    rows: list[Row] = []
    # word2vec, large-ish blocks
    _, store, _, _ = word2vec_scenario(num_models=6,
                                       block_shape=(64, 64),
                                       blocks_per_page=8)
    rows += _compare("word2vec_64x64_l8", store, 8)
    # text classification, two page sizes (paper: 64MB vs 32MB)
    _, store2, _ = classification_scenario(num_models=5, validate=False,
                                           block_shape=(32, 32),
                                           blocks_per_page=8)
    rows += _compare("textclf_32x32_l8", store2, 8)
    rows += _compare("textclf_32x32_l4", store2, 4)
    # heterogeneous-ish: small blocks -> many equivalence classes
    _, store3, _, _ = word2vec_scenario(num_models=4,
                                        block_shape=(32, 32),
                                        blocks_per_page=16, seed=3)
    rows += _compare("word2vec_32x32_l16", store3, 16)
    return rows
