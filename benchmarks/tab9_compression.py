"""Tab. 9 analog: dedup composed with pruning and int8 quantization —
cross-model dedup multiplies with per-model compression."""
from __future__ import annotations

import numpy as np

from .common import Row, store_config
from repro.core import ModelStore
from repro.core.compress import (magnitude_prune, nbytes_sparse,
                                 quantize_int8, quantize_model, prune_model)
from repro.data.pipeline import SyntheticTextTask


def run() -> list:
    rows: list[Row] = []
    task = SyntheticTextTask(vocab=1024, d=64, seed=0)
    variants = {f"m{v}": {"embedding": task.variant_embedding(v)}
                for v in range(4)}
    dense_bytes = sum(t["embedding"].nbytes for t in variants.values())

    def acc_drop(models_fn):
        worst = 0.0
        for v in range(4):
            emb0 = variants[f"m{v}"]["embedding"]
            emb1 = models_fn(v)
            head = task.train_head(emb0, variant=v)
            docs, labels = task.sample(256, variant=v, seed=91 + v)
            worst = max(worst, task.accuracy(emb0, head, docs, labels)
                        - task.accuracy(emb1, head, docs, labels))
        return worst

    # pruning only (CSR cost model)
    pruned = {k: prune_model(t, 0.5) for k, t in variants.items()}
    pr_bytes = sum(nbytes_sparse(t["embedding"]) for t in pruned.values())
    rows.append(("tab9/pruning", 0.0,
                 f"ratio={pr_bytes / dense_bytes:.3f};"
                 f"acc_drop={acc_drop(lambda v: pruned[f'm{v}']['embedding']):.4f}"))

    # quantization only (int8 + scale)
    q_bytes = sum(t["embedding"].nbytes // 4 + 4 for t in variants.values())
    quant = {k: quantize_model(t) for k, t in variants.items()}
    rows.append(("tab9/quantization", 0.0,
                 f"ratio={q_bytes / dense_bytes:.3f};"
                 f"acc_drop={acc_drop(lambda v: quant[f'm{v}']['embedding']):.4f}"))

    def dedup_bytes(models, itembytes=4):
        cfg = store_config(task.base_embed, block_shape=(32, 32),
                           blocks_per_page=8, threshold=8)
        store = ModelStore(cfg)
        for k, t in models.items():
            store.register(k, t)
        scale = itembytes / 4.0
        return store.storage_bytes() * scale, store

    # dedup only
    dd_bytes, store = dedup_bytes(variants)
    rows.append(("tab9/dedup", 0.0,
                 f"ratio={dd_bytes / dense_bytes:.3f};"
                 f"acc_drop={acc_drop(lambda v: store.materialize(f'm{v}', 'embedding')):.4f}"))

    # dedup + pruning (pruned weights still block-similar across models)
    dp_bytes, store_p = dedup_bytes(pruned)
    dp_bytes *= 0.6      # zero-run encoding of pruned pages (CSR-lite)
    rows.append(("tab9/dedup_pruning", 0.0,
                 f"ratio={dp_bytes / dense_bytes:.3f};"
                 f"acc_drop={acc_drop(lambda v: store_p.materialize(f'm{v}', 'embedding')):.4f}"))

    # dedup + quantization (int8 pages)
    dq_bytes, store_q = dedup_bytes(quant, itembytes=1)
    rows.append(("tab9/dedup_quant", 0.0,
                 f"ratio={dq_bytes / dense_bytes:.3f};"
                 f"acc_drop={acc_drop(lambda v: store_q.materialize(f'm{v}', 'embedding')):.4f}"))
    return rows
