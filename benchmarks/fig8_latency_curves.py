"""Figs. 8/9/10 analog: serving latency vs buffer-pool size and storage
tier, dedup vs dense, six word2vec models."""
from __future__ import annotations

import numpy as np

from .common import Row, word2vec_scenario, store_config
from repro.core import ModelStore
from repro.serving.engine import (EmbeddingServingEngine, StorageModel,
                                  WeightServer)


def _serve_virtual_seconds(store, heads, task, cap, storage, batches=30,
                           seed=0):
    server = WeightServer(store, cap, "optimized_mru",
                          StorageModel(storage))
    engine = EmbeddingServingEngine(server, heads)
    rng = np.random.default_rng(seed)
    for b in range(batches):
        v = int(rng.integers(0, len(heads)))
        docs, _ = task.sample(32, variant=v, seed=seed + 100 + b)
        engine.submit(f"w2v-v{v}", docs)
    stats = engine.run()
    return stats.fetch_seconds, server.pool.hit_ratio


def run() -> list:
    rows: list[Row] = []
    task, store, heads, _ = word2vec_scenario(num_models=6)
    dense_cfg = store_config(task.base_embed, threshold=17)
    dense = ModelStore(dense_cfg)
    for name in heads:
        v = int(name.split("v")[-1])
        dense.register(name, {"embedding": task.variant_embedding(v)})

    dedup_pages = store.num_pages()
    for frac in (0.25, 0.5, 1.0):
        cap = max(2, int(dedup_pages * frac))
        for storage in ("ssd", "hdd"):
            t_d, hr_d = _serve_virtual_seconds(store, heads, task, cap,
                                               storage)
            t_b, hr_b = _serve_virtual_seconds(dense, heads, task, cap,
                                               storage)
            speed = t_b / max(1e-9, t_d)
            rows.append((f"fig8/pool{frac}/{storage}",
                         t_d * 1e6 / 30,
                         f"dedup_hit={hr_d:.3f};dense_hit={hr_b:.3f};"
                         f"io_speedup={speed:.2f}x"))
    return rows
