"""Figs. 8/9/10 analog: serving latency vs buffer-pool size and storage
tier, dedup vs dense, six word2vec models — with a **scheduler-policy
axis**: the serial round-robin baseline vs the async engine (grouped
fetches double-buffered against compute) under fifo / round_robin /
dedup_affinity, the latter also with the λ-driven prefetcher."""
from __future__ import annotations

import numpy as np

from .common import Row, word2vec_scenario, store_config
from repro.core import ModelStore
from repro.serving.engine import (EmbeddingServingEngine, StorageModel,
                                  WeightServer)
from repro.serving.prefetch import Prefetcher

# (label, scheduler policy, overlap, prefetch)
SCHED_MODES = [
    ("serial",        "round_robin",    False, False),
    ("async_fifo",    "fifo",           True,  False),
    ("async_rr",      "round_robin",    True,  False),
    ("async_affinity", "dedup_affinity", True,  True),
]


def _serve(store, heads, task, cap, storage, mode, batches=30, seed=0):
    label, sched, overlap, prefetch = mode
    server = WeightServer(store, cap, "optimized_mru",
                          StorageModel(storage))
    engine = EmbeddingServingEngine(
        server, heads, scheduler=sched,
        prefetcher=Prefetcher(server) if prefetch else None,
        overlap=overlap)
    rng = np.random.default_rng(seed)
    for b in range(batches):
        v = int(rng.integers(0, len(heads)))
        docs, _ = task.sample(32, variant=v, seed=seed + 100 + b)
        engine.submit(f"w2v-v{v}", docs)
    stats = engine.run()
    return stats, server.pool.hit_ratio


def run() -> list:
    rows: list[Row] = []
    task, store, heads, _ = word2vec_scenario(num_models=6)
    dense_cfg = store_config(task.base_embed, threshold=17)
    dense = ModelStore(dense_cfg)
    for name in heads:
        v = int(name.split("v")[-1])
        dense.register(name, {"embedding": task.variant_embedding(v)})

    dedup_pages = store.num_pages()
    batches = 30
    for frac in (0.25, 0.5, 1.0):
        cap = max(2, int(dedup_pages * frac))
        for storage in ("ssd", "hdd"):
            # dedup-vs-dense I/O comparison (serial, as in the paper)
            s_d, hr_d = _serve(store, heads, task, cap, storage,
                               SCHED_MODES[0])
            s_b, hr_b = _serve(dense, heads, task, cap, storage,
                               SCHED_MODES[0])
            speed = s_b.fetch_seconds / max(1e-9, s_d.fetch_seconds)
            rows.append((f"fig8/pool{frac}/{storage}",
                         s_d.fetch_seconds * 1e6 / batches,
                         f"dedup_hit={hr_d:.3f};dense_hit={hr_b:.3f};"
                         f"io_speedup={speed:.2f}x"))
            # scheduler-policy axis: end-to-end virtual makespan
            serial_makespan = s_d.makespan_seconds
            for mode in SCHED_MODES[1:]:
                s, hr = _serve(store, heads, task, cap, storage, mode)
                rows.append((
                    f"fig8/pool{frac}/{storage}/{mode[0]}",
                    s.makespan_seconds * 1e6 / batches,
                    f"hit={hr:.3f};makespan_ms={s.makespan_seconds*1e3:.2f};"
                    f"serial_ms={serial_makespan*1e3:.2f};"
                    f"speedup={serial_makespan/max(1e-9, s.makespan_seconds):.2f}x"))
    return rows
