"""Tab. 4 analog: dedup across heterogeneous architectures.

Scenario-1: four text models with different embedding shapes (nnlm128 /
nnlm50 / wiki250 / wiki500 analogs).  Scenario-2: four FFNNs of different
layer sizes.  Scenario-3: one embedding model + one FFNN.
Blocks w/o vs w/ dedup, pages w/o vs w/ dedup, max accuracy drop.
"""
from __future__ import annotations

import numpy as np

from .common import Row, store_config
from repro.core import ModelStore
from repro.data.pipeline import SyntheticTextTask


def _embed_models(seed=0):
    """Different dims share a 'pretraining lineage': truncated columns of
    one wide base matrix (mirrors nnlm/wiki shared-corpus similarity)."""
    task = SyntheticTextTask(vocab=1536, d=128, seed=seed)
    wide = task.base_embed
    out = {
        "nnlm128": wide[:1024, :128],
        "nnlm50": wide[:1024, :64],
        "wiki250": wide[:1536, :96] + 1e-4,
        "wiki500": wide[:1536, :128],
    }
    return task, {k: np.ascontiguousarray(v) for k, v in out.items()}


def _ffnn_models(seed=1):
    rng = np.random.default_rng(seed)
    shared = (rng.standard_normal((1024, 256)) * 0.05).astype(np.float32)
    models = {}
    for i, (f, h) in enumerate([(512, 256), (1024, 128), (1024, 256),
                                (768, 192)]):
        W1 = shared[:f, :h].copy()
        W2 = (rng.standard_normal((h, 128)) * 0.05).astype(np.float32)
        models[f"xc-{i}"] = {"W1": W1, "W2": W2}
    return models


def _measure(store: ModelStore, tensors_per_model) -> str:
    total_blocks = sum(e.grid.num_blocks
                       for m in store.dedup.models.values()
                       for e in m.tensors.values())
    distinct = store.dedup.num_distinct
    pages = store.num_pages()
    dense_pages = sum(-(-e.grid.num_blocks // store.cfg.blocks_per_page)
                      for m in store.dedup.models.values()
                      for e in m.tensors.values())
    return (f"blocks={total_blocks};distinct={distinct};"
            f"pages_dense={dense_pages};pages_dedup={pages};"
            f"reduction={dense_pages / max(1, pages):.2f}x")


def run() -> list:
    rows: list[Row] = []
    bs = (32, 32)

    # scenario 1: heterogeneous embeddings
    task, embeds = _embed_models()
    cfg = store_config(embeds["wiki500"], block_shape=bs, blocks_per_page=8,
                       threshold=8)
    s1 = ModelStore(cfg)
    for name, emb in embeds.items():
        s1.register(name, {"embedding": emb})
    rows.append(("tab4/scenario1", 0.0, _measure(s1, embeds)))

    # scenario 2: heterogeneous FFNNs
    ffnn = _ffnn_models()
    cfg2 = store_config(ffnn["xc-2"]["W1"], block_shape=bs,
                        blocks_per_page=8, threshold=10)
    s2 = ModelStore(cfg2)
    for name, t in ffnn.items():
        s2.register(name, dict(t))
    rows.append(("tab4/scenario2", 0.0, _measure(s2, ffnn)))

    # scenario 3: one of each
    cfg3 = store_config(embeds["wiki500"], block_shape=bs,
                        blocks_per_page=8, threshold=10)
    s3 = ModelStore(cfg3)
    s3.register("wiki500", {"embedding": embeds["wiki500"]})
    s3.register("xc-2", dict(ffnn["xc-2"]))
    rows.append(("tab4/scenario3", 0.0, _measure(s3, None)))
    return rows
