"""Crash-recovery benchmark -> BENCH_recovery.json.

Two halves of the DESIGN.md §11 durability story, measured:

  * **Journal replay cost** — a committed store is wrecked the way a
    crash mid-save wrecks it (k pending intents in the journal, k
    orphan pages no manifest references, k ``*.tmp`` staging files) and
    ``recover_backend`` is timed cleaning it up.  The recovery report's
    counts must equal the planted wreckage exactly — recovery that
    deletes the wrong number of things is worse than no recovery — and
    the clean-open cost (empty journal) is recorded as the floor every
    ordinary open pays.
  * **Warm restart under traffic** — the same open-loop request stream
    is served twice from one committed store: once to completion, and
    once killed after K dispatched batches (the frontend's snapshot is
    all that survives) then resumed on a FRESH engine whose pools
    rebuild lazily from the store.  Claims, all zero-tolerance on the
    virtual clock: the at-most-once ledger balances (served + shed ==
    offered, no id served twice), the union of pre- and post-restart
    logits is bit-exact against the uninterrupted run, at least one
    request was re-admitted (the restart did real work), and the
    resumed run's p99 stays within ``RESTART_P99_FACTOR`` of the
    uninterrupted p99.

Run standalone (``python -m benchmarks.bench_recovery [--smoke]``) or
through ``benchmarks.run``.  Always writes BENCH_recovery.json at the
repo root so CI tracks the recovery-cost trajectory PR over PR.
"""
from __future__ import annotations

import json
import os
import tempfile
import time
from typing import Dict, List

import numpy as np

from .common import Row, word2vec_scenario
from repro.core.store import ModelStore
from repro.serving.engine import (EmbeddingServingEngine, StorageModel,
                                  WeightServer)
from repro.serving.frontend import BatchComputeModel, ServingFrontend
from repro.serving.traffic import OpenLoopTraffic
from repro.storage.crashpoints import prime_store
from repro.storage.journal import Journal, recover_backend
from repro.storage.localdir import LocalDirBackend

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_recovery.json")

#: the resumed run replays the exact same virtual-clock history (queues,
#: EMA estimators and the clock itself are restored bit-for-bit), so its
#: p99 should EQUAL the uninterrupted run's; the factor is headroom for
#: a deliberate future change to resume ordering, not for noise
RESTART_P99_FACTOR = 1.5
SEED = 11
ZIPF = 1.1
#: deterministic virtual compute (same spelling as bench_traffic)
COMPUTE = BatchComputeModel(base=4e-4, per_request=4e-5)


# ------------------------------------------------ journal replay cost ----
def _wreck(path: str, k: int) -> None:
    """Strand the wreckage a crash mid-save leaves behind a committed
    store: ``k`` pending intents, ``k`` unreferenced pages, ``k`` temp
    staging files."""
    backend = LocalDirBackend(path)
    jr = Journal(backend)
    rng = np.random.default_rng(1000 + k)
    orphans: Dict[str, np.ndarray] = {}
    for i in range(k):
        jr.begin("save", keep=[])
        orphans[f"orphan{i:08d}"] = \
            rng.standard_normal((16, 16)).astype(np.float32)
    backend.put_pages(orphans)
    for i in range(k):
        with open(os.path.join(path, f"stray-{i:04d}.npy.tmp"), "w") as f:
            f.write("staging debris")
    backend.close()


def _recover_case(base: str, k: int, repeats: int = 3) -> Dict:
    """Best-of-N recovery timing at journal length ``k`` (every repeat
    wrecks a fresh copy of the primed store — recovery is destructive,
    so the wreckage cannot be reused)."""
    best = float("inf")
    counts_exact = True
    for rep in range(repeats):
        path = os.path.join(base, f"j{k}-r{rep}")
        prime_store(f"file://{path}")
        _wreck(path, k)
        backend = LocalDirBackend(path)
        t0 = time.perf_counter()
        report = recover_backend(backend)
        best = min(best, time.perf_counter() - t0)
        counts_exact = counts_exact and (
            report.recovered
            and report.pending_intents == k
            and report.orphan_pages_deleted == k
            and report.temp_files_swept == k)
        # recovery must converge: a second pass is a clean no-op
        counts_exact = counts_exact and not recover_backend(backend).recovered
        backend.close()
    # the floor every ordinary open pays: replaying a CLEAN journal
    clean_backend = LocalDirBackend(os.path.join(base, f"j{k}-r0"))
    t0 = time.perf_counter()
    for _ in range(8):
        recover_backend(clean_backend)
    clean_ms = (time.perf_counter() - t0) / 8 * 1e3
    clean_backend.close()
    return {"journal_len": k, "recover_ms": best * 1e3,
            "orphan_pages": k, "temp_files": k,
            "clean_open_ms": clean_ms, "counts_exact": counts_exact}


# ------------------------------------------------ warm restart -----------
def _payload_fn(task, docs_per_req):
    def payload(model, rid, rng):
        v = int(model.rsplit("-v", 1)[1])
        docs, _ = task.sample(docs_per_req, variant=v, seed=50_000 + rid)
        return docs
    return payload


def _restart_case(base: str, smoke: bool) -> Dict:
    scenario = dict(num_models=4, vocab=512, d=32,
                    block_shape=(32, 32), blocks_per_page=4)
    n_requests = 120 if smoke else 400
    kill_after = 5
    max_batch, docs_per_req = 4, 2
    rate, slo_s = 400.0, 0.2
    task, store, heads, _ = word2vec_scenario(**scenario)
    models = sorted(heads)
    url = f"file://{os.path.join(base, 'serving-store')}"
    store.save(url)
    cap = max(2, store.num_pages() // 2)

    def _gen():
        return OpenLoopTraffic(models, rate=rate, zipf_alpha=ZIPF,
                               slo_s=slo_s, seed=SEED,
                               payload_fn=_payload_fn(task, docs_per_req))

    def _engine():
        # a FRESH open every time: pools rebuild lazily from the store,
        # exactly what a restarted serving process does
        opened = ModelStore.open(url)
        server = WeightServer(opened, cap, "optimized_mru",
                              StorageModel("dram"))
        return EmbeddingServingEngine(server, heads, scheduler="fifo",
                                      overlap=True)

    # -- golden: the same stream served uninterrupted ----------------------
    fe0 = ServingFrontend(_engine(), max_batch=max_batch,
                          compute_model=COMPUTE, capture=True)
    st0 = fe0.run(_gen().generate(n_requests))
    golden = {rid: v.copy() for rid, v in fe0.results.items()}
    p99_golden = float(np.percentile(
        np.asarray(st0.request_latencies), 99)) * 1e3

    # -- interrupted: kill after K dispatches, resume from the snapshot ----
    snap_path = os.path.join(base, "fe-snapshot.json")
    fe1 = ServingFrontend(_engine(), max_batch=max_batch,
                          compute_model=COMPUTE, capture=True,
                          snapshot_path=snap_path)
    fe1.run(_gen().generate(n_requests), max_dispatches=kill_after)
    results_before = {rid: v.copy() for rid, v in fe1.results.items()}
    # simulated process death: only the snapshot file and the committed
    # store survive; engine, pools and the frontend object are gone
    with open(snap_path) as f:
        snap = json.load(f)
    t0 = time.perf_counter()
    fe2 = ServingFrontend.restore(_engine(), snap, _gen().generate(
        n_requests), compute_model=COMPUTE, capture=True,
        snapshot_path=snap_path)
    restore_ms = (time.perf_counter() - t0) * 1e3
    st2 = fe2.run(_gen().generate(n_requests))
    fe2.assert_ledger_conserved()
    p99_restart = float(np.percentile(
        np.asarray(st2.request_latencies), 99)) * 1e3

    dup_rids = set(results_before) & set(fe2.results)
    combined = dict(results_before)
    combined.update(fe2.results)
    logits_exact = (set(combined) == set(golden)
                    and all(np.array_equal(combined[rid], golden[rid])
                            for rid in golden))
    led = fe2.ledger
    ledger_conserved = (
        len(led.served) + len(led.shed) == len(led.offered)
        and not led.in_flight and fe2.pending_requests() == 0
        and len(led.offered) == n_requests)
    # the store a restarted process reopens must already be clean
    sb = LocalDirBackend(os.path.join(base, "serving-store"))
    store_clean = not sb.journal_records() and sb.sweep_temp() == 0
    sb.close()
    return {
        "requests": n_requests, "kill_after": kill_after,
        "max_batch": max_batch, "docs_per_req": docs_per_req,
        "rate_per_s": rate, "slo_ms": slo_s * 1e3,
        "scenario": scenario, "capacity_pages": cap,
        "served_before_kill": len(results_before),
        "readmitted": int(led.readmitted),
        "restore_ms": restore_ms,
        "p99_golden_ms": p99_golden,
        "p99_restart_ms": p99_restart,
        "duplicates": len(dup_rids),
        "logits_exact": bool(logits_exact),
        "ledger_conserved": bool(ledger_conserved),
        "store_clean": bool(store_clean),
    }


def run(smoke: bool = False) -> List[Row]:
    lens = (1, 8, 32) if smoke else (1, 8, 32, 256)
    rows: List[Row] = []
    configs = []
    with tempfile.TemporaryDirectory(prefix="bench-recovery-") as base:
        for k in lens:
            c = _recover_case(base, k)
            configs.append(c)
            rows.append((
                f"recovery/journal{k}",
                c["recover_ms"] * 1e3,             # us per recovery
                f"orphans={c['orphan_pages']};temps={c['temp_files']};"
                f"clean_open_ms={c['clean_open_ms']:.3f};"
                f"exact={int(c['counts_exact'])}"))
        restart = _restart_case(base, smoke)
    rows.append((
        "recovery/restart",
        restart["restore_ms"] * 1e3,               # us per restore
        f"readmitted={restart['readmitted']};"
        f"dups={restart['duplicates']};"
        f"exact={int(restart['logits_exact'])};"
        f"p99_ms={restart['p99_restart_ms']:.3f}"))

    payload = {
        "bench": "recovery",
        "scenario": {"journal_lens": list(lens),
                     "requests": restart["requests"],
                     "kill_after": restart["kill_after"],
                     "rate_per_s": restart["rate_per_s"],
                     "slo_ms": restart["slo_ms"],
                     "max_batch": restart["max_batch"],
                     "docs_per_req": restart["docs_per_req"],
                     "seed": SEED, "zipf": ZIPF, "smoke": smoke},
        "configs": configs,
        "restart": restart,
        # zero-tolerance internal claims (deterministic: virtual clock
        # latencies, content-addressed recovery, seeded streams)
        "recovery_counts_exact": all(c["counts_exact"] for c in configs),
        "restart_ledger_conserved": restart["ledger_conserved"],
        "restart_no_duplicates": restart["duplicates"] == 0,
        "restart_logits_exact": restart["logits_exact"],
        "restart_did_work": restart["readmitted"] > 0
                            and restart["served_before_kill"] > 0,
        "restart_p99_bounded":
            restart["p99_restart_ms"]
            <= RESTART_P99_FACTOR * restart["p99_golden_ms"],
        "restart_p99_factor_limit": RESTART_P99_FACTOR,
        "store_recovery_clean": restart["store_clean"],
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    return rows


def main() -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small fast configuration for CI")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    with open(JSON_PATH) as f:
        payload = json.load(f)
    for claim in ("recovery_counts_exact", "restart_ledger_conserved",
                  "restart_no_duplicates", "restart_logits_exact",
                  "restart_did_work", "restart_p99_bounded",
                  "store_recovery_clean"):
        if not payload[claim]:
            print(f"# WARN recovery claim failed: {claim}")
    print(f"# wrote {os.path.abspath(JSON_PATH)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
