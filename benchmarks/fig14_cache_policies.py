"""Fig. 14 analog: cache hit ratio across replacement policies (LRU, MRU,
LocalitySet-M/L, Optimized-M/L with Eq. 2) on multi-model traffic — now
crossed with the batch-scheduler axis (round_robin vs dedup_affinity):
replacement decides who *stays*, scheduling decides who *arrives next*,
and the two compound."""
from __future__ import annotations

import numpy as np

from .common import Row, word2vec_scenario
from repro.core.bufferpool import POLICIES
from repro.serving.engine import (EmbeddingServingEngine, StorageModel,
                                  WeightServer)

SCHEDULERS = ("round_robin", "dedup_affinity")


def run() -> list:
    rows: list[Row] = []
    task, store, heads, _ = word2vec_scenario(num_models=6)
    cap = max(2, store.num_pages() // 3)      # pressure: third fits
    for policy in POLICIES:
        hits = {}
        for sched in SCHEDULERS:
            server = WeightServer(store, cap, policy, StorageModel("ssd"))
            engine = EmbeddingServingEngine(server, heads, scheduler=sched,
                                            overlap=(sched != "round_robin"))
            rng = np.random.default_rng(5)
            for b in range(60):
                v = int(rng.integers(0, 6))
                docs, _ = task.sample(24, variant=v, seed=300 + b)
                engine.submit(f"w2v-v{v}", docs)
            engine.run()
            hits[sched] = server.pool.hit_ratio
            rows.append((f"fig14/{policy}/{sched}", 0.0,
                         f"hit_ratio={server.pool.hit_ratio:.4f}"))
        rows.append((f"fig14/{policy}", 0.0,
                     f"hit_ratio={hits['round_robin']:.4f};"
                     f"affinity_delta="
                     f"{hits['dedup_affinity'] - hits['round_robin']:+.4f}"))
    return rows
