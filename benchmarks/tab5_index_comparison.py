"""Tab. 5 / Fig. 12 analog: duplicate-detection strategies compared on
compression, per-block index query time, and post-dedup accuracy."""
from __future__ import annotations

import time

import numpy as np

from .common import Row
from repro.core.blocks import block_tensor, unblock_tensor
from repro.core.dedup import (DedupConfig, Deduplicator, exact_dedup,
                              minhash_dedup, pairwise_dedup)
from repro.core.lsh import LSHConfig, estimate_r
from repro.data.pipeline import SyntheticTextTask


def run() -> list:
    rows: list[Row] = []
    task = SyntheticTextTask(vocab=1024, d=64, seed=0)
    bs = (32, 32)
    embs = [task.variant_embedding(v) for v in range(4)]
    all_blocks, grids = [], []
    for e in embs:
        b, g = block_tensor(e, bs)
        all_blocks.append(b)
        grids.append(g)
    stacked = np.concatenate(all_blocks)
    head = task.train_head(embs[1], variant=1)
    docs, labels = task.sample(256, variant=1, seed=77)

    def accuracy_of(bmap, reps):
        """Rebuild variant-1's embedding from a dedup mapping."""
        n0 = len(all_blocks[0])
        rec_blocks = reps[bmap[n0:2 * n0]]
        emb = unblock_tensor(rec_blocks, grids[1])
        return task.accuracy(emb, head, docs, labels)

    acc_orig = task.accuracy(embs[1], head, docs, labels)
    rows.append(("tab5/original", 0.0, f"blocks={len(stacked)};"
                 f"acc={acc_orig:.4f}"))

    # Mistique exact
    bmap, n, dt = exact_dedup(stacked)
    reps = np.stack([stacked[np.nonzero(bmap == i)[0][0]]
                     for i in range(n)])
    rows.append(("tab5/mistique_exact", dt * 1e6,
                 f"distinct={n};acc={accuracy_of(bmap, reps):.4f}"))

    # Mistique approximate (MinHash) — small subset, inherently slow
    sub = stacked[: 2 * len(all_blocks[0])]
    bmap_m, n_m, dt_m = minhash_dedup(sub, num_perm=16)
    rows.append(("tab5/mistique_minhash", dt_m * 1e6,
                 f"distinct={n_m}(subset={len(sub)})"))

    # Enhanced pairwise with magnitude ordering
    r = estimate_r(stacked, quantile=0.5)
    bmap_p, n_p, dt_p = pairwise_dedup(stacked, dist_threshold=r)
    reps_p = np.stack([stacked[i] for i in np.unique(bmap_p)]) \
        if False else None
    uniq = np.unique(bmap_p)
    remap = {int(u): i for i, u in enumerate(uniq)}
    reps_p = stacked[uniq]
    bmap_p2 = np.array([remap[int(x)] for x in bmap_p])
    rows.append(("tab5/enhanced_pairwise", dt_p * 1e6,
                 f"distinct={n_p};acc={accuracy_of(bmap_p2, reps_p):.4f}"))

    # Proposed: L2-LSH index (Alg. 1, no finetune)
    d = Deduplicator(DedupConfig(
        block_shape=bs,
        lsh=LSHConfig(num_bands=16, rows_per_band=4, r=r,
                      collision_threshold=8),
        validate=False))
    t0 = time.perf_counter()
    for v, e in enumerate(embs):
        d.add_model(f"m{v}", {"embedding": e})
    dt_l = (time.perf_counter() - t0) / len(stacked)
    emb1 = d.materialize("m1", "embedding")
    acc_l = task.accuracy(emb1, head, docs, labels)
    rows.append(("tab5/proposed_l2lsh", dt_l * 1e6,
                 f"distinct={d.num_distinct};acc={acc_l:.4f}"))
    return rows
