"""Fig. 13 analog: periodic-validation overheads — validation set size vs
accuracy preserved, compression achieved, and per-validation latency."""
from __future__ import annotations

import time

import numpy as np

from .common import Row, store_config
from repro.core import ModelStore
from repro.data.pipeline import SyntheticTextTask


def run() -> list:
    rows: list[Row] = []
    task = SyntheticTextTask(vocab=1024, d=64, seed=0)
    for n_val in (32, 128, 512):
        cfg = store_config(task.base_embed, block_shape=(32, 32),
                           blocks_per_page=8, threshold=6,
                           validate=True, drop_t=0.02, k=16)
        store = ModelStore(cfg)
        val_t = []
        for v in range(3):
            emb = task.variant_embedding(v)
            head = task.train_head(emb, variant=v)
            docs, labels = task.sample(n_val, variant=v, seed=v + 7)

            def ev(tensors, head=head, docs=docs, labels=labels):
                t0 = time.perf_counter()
                acc = task.accuracy(tensors["embedding"], head, docs,
                                    labels)
                val_t.append(time.perf_counter() - t0)
                return acc

            store.register(f"m{v}", {"embedding": emb}, evaluator=ev)
        ratio = store.storage_bytes() / max(1, store.dense_bytes())
        drops = [m.accuracy_before - m.accuracy_after
                 for m in store.dedup.models.values()
                 if m.accuracy_after is not None]
        n_validations = sum(m.num_validations
                            for m in store.dedup.models.values())
        rows.append((
            f"fig13/val{n_val}",
            float(np.mean(val_t)) * 1e6 if val_t else 0.0,
            f"val_bytes={n_val * task.doc_len * 4};"
            f"compression_ratio={ratio:.3f};"
            f"max_drop={max(drops) if drops else 0:.4f};"
            f"validations={n_validations}"))
    return rows
