"""Tab. 3 / Fig. 11 analog: transfer-learning FFNNs (shared W1), storage
reduction + inference latency dedup vs dense, via the dedup_matmul path."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .common import Row, ffnn_scenario, timed
from repro.kernels import ref
from repro.serving.engine import StorageModel, WeightServer


def run() -> list:
    rows: list[Row] = []
    store, models = ffnn_scenario(num_models=3)
    red = store.dense_bytes() / max(1, store.storage_bytes())
    rows.append(("tab3/storage_reduction/m3", 0.0, f"{red:.2f}x"))

    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((64, 2048)), jnp.float32)

    # dedup path: virtual W1 through the shared pool (ref oracle = the
    # jnp lowering of the Pallas kernel), W2 dense per model
    vt = store.virtual_tensor("ffnn-1", "W1")
    pool = jnp.asarray(store.page_pool().reshape(-1, 64, 64))
    bmap = jnp.asarray(vt.block_map.reshape(vt.grid.grid))
    W2 = jnp.asarray(models["ffnn-1"]["W2"])

    def dedup_infer():
        h = jnp.maximum(ref.dedup_matmul(x, pool, bmap), 0.0)
        return (h @ W2).block_until_ready()

    us_dedup, _ = timed(dedup_infer, repeats=5)
    W1 = jnp.asarray(models["ffnn-1"]["W1"])

    def dense_infer():
        h = jnp.maximum(x @ W1, 0.0)
        return (h @ W2).block_until_ready()

    us_dense, _ = timed(dense_infer, repeats=5)
    rows.append(("tab3/infer_dedup", us_dedup, "virtual-W1"))
    rows.append(("tab3/infer_dense", us_dense,
                 f"overhead={us_dedup / max(1e-9, us_dense):.2f}x"))

    # paging latency under memory pressure: shared W1 pages hit across
    # model switches (the Fig. 11 effect)
    for storage in ("ssd", "hdd"):
        server = WeightServer(store, max(2, store.num_pages() // 2),
                              "optimized_mru", StorageModel(storage))
        t = 0.0
        for rep in range(6):
            for name in models:
                t += server.access_pages(
                    name, server.tensor_pages(name, "W1"))
                t += server.access_pages(
                    name, server.tensor_pages(name, "W2"))
        rows.append((f"tab3/page_fetch/{storage}", t * 1e6 / 18,
                     f"hit={server.pool.hit_ratio:.3f}"))
    return rows
