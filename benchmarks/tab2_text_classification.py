"""Tab. 2 analog: private/shared pages + accuracy before/after dedup for
five text-classification variants (Sec. 7.1.2)."""
from __future__ import annotations

from collections import defaultdict

from .common import Row, classification_scenario


def run() -> list:
    task, store, rows_info = classification_scenario(num_models=5)
    pk = store.packing
    counts = defaultdict(int)
    for (m, t), pids in pk.tensor_pages.items():
        for p in set(pids):
            counts[p] += 1
    rows: list[Row] = []
    for name, info in rows_info.items():
        pids = set(pk.tensor_pages[(name, "embedding")])
        shared = sum(1 for p in pids if counts[p] > 1)
        private = len(pids) - shared
        rows.append((
            f"tab2/{name}", 0.0,
            f"private={private};shared={shared};"
            f"auc_before={info['acc_before']:.4f};"
            f"auc_after={info['acc_after']:.4f}"))
    total = store.num_pages()
    dense_pages = sum(-(-e.grid.num_blocks // store.cfg.blocks_per_page)
                      for m in store.dedup.models.values()
                      for e in m.tensors.values())
    rows.append(("tab2/total_pages", 0.0,
                 f"dedup={total};dense={dense_pages};"
                 f"reduction={dense_pages / max(1, total):.2f}x"))
    return rows
