"""Tab. 8 analog: model-update handling — Approach-1 (remove+reinsert)
vs Approach-2 (LSH delta, skipping unchanged blocks)."""
from __future__ import annotations

import time

import numpy as np

from .common import Row, store_config
from repro.core import ModelStore
from repro.data.pipeline import SyntheticTextTask


def run() -> list:
    rows: list[Row] = []
    task = SyntheticTextTask(vocab=1024, d=64, seed=0)
    for approach in (1, 2):
        cfg = store_config(task.base_embed, block_shape=(32, 32),
                           blocks_per_page=8, threshold=8)
        store = ModelStore(cfg)
        for v in range(3):
            store.register(f"m{v}", {"embedding": task.variant_embedding(v)})
        # update m1: perturb 5% of rows (the wiki500_imdbm update)
        emb = task.variant_embedding(1)
        rng = np.random.default_rng(42)
        touched = rng.choice(task.vocab, task.vocab // 20, replace=False)
        emb2 = emb.copy()
        emb2[touched] += (rng.standard_normal((len(touched), task.d))
                          * 0.05).astype(np.float32)
        t0 = time.perf_counter()
        res = store.update("m1", {"embedding": emb2}, approach=approach)
        dt = time.perf_counter() - t0
        store.repack()
        ratio = store.storage_bytes() / max(1, store.dense_bytes())
        err = np.abs(store.materialize("m1", "embedding") - emb2).max()
        rows.append((f"tab8/approach{approach}", dt * 1e6,
                     f"compression_ratio={ratio:.3f};"
                     f"validations={res.num_validations};"
                     f"max_err={err:.4f}"))
    return rows
