"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json; prefers the ``unrolled`` accounting
variant (exact per-layer costs) and falls back to the rolled baseline.
"""
from __future__ import annotations

import glob
import json
import os

from .common import Row

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_cells(mesh="single", prefer_variant="unrolled"):
    cells = {}
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        rec = json.load(open(path))
        m = rec.get("meta", {})
        if not m or (("multi" if m.get("multi_pod") else "single") != mesh):
            continue
        key = (m.get("arch"), m.get("shape"))
        variant = m.get("variant", "baseline")
        cur = cells.get(key)
        if cur is None or variant == prefer_variant:
            if cur is not None and cur["meta"].get("variant") == \
                    prefer_variant and variant != prefer_variant:
                continue
            cells[key] = rec
    return cells


def run() -> list:
    rows: list[Row] = []
    cells = load_cells()
    for (arch, shape), rec in sorted(cells.items()):
        if rec["status"] == "skipped":
            rows.append((f"roofline/{arch}/{shape}", 0.0,
                         "skipped:" + rec["reason"][:48]))
            continue
        if rec["status"] != "ok":
            rows.append((f"roofline/{arch}/{shape}", 0.0, "error"))
            continue
        rl = rec["roofline"]
        m = rec["meta"]
        flops = rec.get("cost_analysis", {}).get("flops")
        u = (m["model_flops"] / m["devices"] / flops) if flops else None
        rows.append((
            f"roofline/{arch}/{shape}",
            max(rl["compute_s"], rl["memory_s"], rl["collective_s"]) * 1e6,
            f"dominant={rl['dominant'].replace('_s', '')};"
            f"compute={rl['compute_s']:.2e};memory={rl['memory_s']:.2e};"
            f"collective={rl['collective_s']:.2e};"
            f"useful={u:.2f}" if u else "useful=?"))
    return rows
