"""Tab. 6 analog: LSH band-collision threshold sweep — the validation-free
knob trading compression ratio against accuracy."""
from __future__ import annotations

from .common import Row, store_config
from repro.core import ModelStore
from repro.data.pipeline import SyntheticTextTask


def run() -> list:
    rows: list[Row] = []
    task = SyntheticTextTask(vocab=1024, d=64, seed=0)
    for threshold in (4, 6, 8, 10, 12, 14):
        cfg = store_config(task.base_embed, block_shape=(32, 32),
                           blocks_per_page=8, threshold=threshold)
        store = ModelStore(cfg)
        worst_drop = 0.0
        for v in range(4):
            emb = task.variant_embedding(v)
            head = task.train_head(emb, variant=v)
            docs, labels = task.sample(256, variant=v, seed=31 + v)
            acc0 = task.accuracy(emb, head, docs, labels)
            store.register(f"m{v}", {"embedding": emb})
            acc1 = task.accuracy(store.materialize(f"m{v}", "embedding"),
                                 head, docs, labels)
            worst_drop = max(worst_drop, acc0 - acc1)
        ratio = store.storage_bytes() / max(1, store.dense_bytes())
        rows.append((f"tab6/threshold_{threshold}", 0.0,
                     f"compression_ratio={ratio:.3f};"
                     f"acc_drop={worst_drop:.4f}"))
    return rows
