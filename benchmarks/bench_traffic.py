"""Open-loop traffic benchmark -> BENCH_traffic.json.

The paper's Fig.-8 claim under *load*: individual requests arriving
over (virtual) time, not pre-built batches.  For each offered-load rung
(a fraction of the measured naive service capacity µ) the same Poisson/
Zipf arrival stream is served twice through a memory-pressured server:

  * ``slo``   — the :class:`ServingFrontend`: continuous batch
    formation under the SLO, cost-based admission against the resident
    set, shedding of dead-on-arrival requests.
  * ``naive`` — per-arrival FIFO dispatch, one request per batch, no
    admission, no shedding: what a serving tier without a front end
    does.

Recorded per rung and policy: served-request latency p50/p99, goodput
(offered requests served within SLO), sheds, SLO misses.  The internal
claim — **SLO-aware formation + admission beats naive dispatch on p99
at the highest load rung** (where the naive queue grows without bound
while formation amortizes fetches and shedding keeps the served tail
inside the SLO) — is zero-tolerance in ``check_bench_regression.py``:
every quantity here lives on the virtual clock (deterministic fetch
seconds + a :class:`BatchComputeModel` for compute), so the whole JSON
is bit-stable under the fixed seed and there is no runner-noise excuse.

Run standalone (``python -m benchmarks.bench_traffic [--smoke]``) or
through ``benchmarks.run``.  Always writes BENCH_traffic.json at the
repo root so CI tracks the goodput/latency trajectory PR over PR.
"""
from __future__ import annotations

import contextlib
import json
import os
from typing import List

import numpy as np

from .common import Row, word2vec_scenario
from repro.serving.engine import (EmbeddingServingEngine, StorageModel,
                                  WeightServer)
from repro.serving.frontend import BatchComputeModel, ServingFrontend
from repro.serving.traffic import OpenLoopTraffic

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_traffic.json")
TRACE_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_traffic_trace.json")

#: offered load rungs as fractions of the measured naive capacity µ:
#: comfortably under, near saturation, and well past it
LOAD_FRACS = (0.5, 0.9, 2.0)
SEED = 11
ZIPF = 1.1
#: deterministic virtual compute: base + per-request seconds per batch
COMPUTE = BatchComputeModel(base=4e-4, per_request=4e-5)


def _payload_fn(task, docs_per_req):
    def payload(model, rid, rng):
        v = int(model.rsplit("-v", 1)[1])
        docs, _ = task.sample(docs_per_req, variant=v, seed=40_000 + rid)
        return docs
    return payload


def _engine(store, heads, cap):
    server = WeightServer(store, cap, "optimized_mru",
                          StorageModel("ssd"))
    return EmbeddingServingEngine(server, heads, scheduler="fifo",
                                  overlap=True)


def _serve(store, heads, cap, task, models, rate, slo_s, n_requests,
           policy, max_batch, docs_per_req, trace_path=None):
    """One policy pass over a freshly generated (identical: same seed)
    arrival stream against a fresh server; returns the metrics dict.
    ``trace_path``: record this pass with a clock-bound tracer and
    write the Chrome-trace there (the bench numbers are unchanged —
    tracing never touches the virtual clock's arithmetic)."""
    gen = OpenLoopTraffic(models, rate=rate, zipf_alpha=ZIPF,
                          slo_s=slo_s, seed=SEED,
                          payload_fn=_payload_fn(task, docs_per_req))
    engine = _engine(store, heads, cap)
    fe = ServingFrontend(engine, max_batch=max_batch, policy=policy,
                         compute_model=COMPUTE, capture=False)
    tracer = None
    activate = contextlib.nullcontext()
    if trace_path:
        from repro.obs import Tracer, use_tracer
        tracer = Tracer(clock=fe.clock)
        activate = use_tracer(tracer)
    with activate:
        st = fe.run(gen.generate(n_requests))
    # rung teardown: the channel ledger must account for every virtual
    # second this pass booked (frontend.run also asserts; cheap here)
    fe.clock.assert_conserved()
    if tracer is not None:
        from repro.obs import write_trace
        tracer.assert_matches_clock(fe.clock)
        write_trace(trace_path, tracer, clock=fe.clock)
    lat = np.asarray(st.request_latencies, dtype=np.float64)
    served = len(lat)
    return {
        "policy": policy,
        "offered": st.offered_requests,
        "served": served,
        "shed": st.shed_requests,
        "slo_misses": st.slo_misses,
        "goodput": st.goodput,
        "batches": st.batches,
        "p50_ms": float(np.percentile(lat, 50)) * 1e3 if served else None,
        "p99_ms": float(np.percentile(lat, 99)) * 1e3 if served else None,
        "queue_p50_ms": float(np.percentile(
            np.asarray(st.queue_latencies), 50)) * 1e3 if served else None,
        "hit_ratio": engine.server.pool.hit_ratio,
        "clock_ms": fe.clock.now * 1e3,
    }


def run(smoke: bool = False, trace: bool = False) -> List[Row]:
    if smoke:
        scenario = dict(num_models=4, vocab=512, d=32,
                        block_shape=(32, 32), blocks_per_page=4)
        n_requests, max_batch, docs_per_req = 150, 8, 2
    else:
        scenario = dict(num_models=6, vocab=1024, d=32,
                        block_shape=(32, 32), blocks_per_page=4)
        n_requests, max_batch, docs_per_req = 600, 8, 2
    task, store, heads, _ = word2vec_scenario(**scenario)
    models = sorted(heads)   # rank order for Zipf popularity
    cap = max(2, store.num_pages() // 2)   # memory-pressured pool

    # -- measure naive capacity µ (deterministic probe) ---------------------
    # a low-rate naive pass has no queueing, so its mean service time is
    # the per-request cost floor; µ = 1/s̄ is the saturation rate
    probe = _serve(store, heads, cap, task, models, rate=1.0, slo_s=10.0,
                   n_requests=40, policy="naive", max_batch=max_batch,
                   docs_per_req=docs_per_req)
    mean_service_s = probe["clock_ms"] * 1e-3 / probe["served"] \
        if probe["served"] else 1e-3
    # clock includes idle between sparse arrivals; use service latencies
    # instead: p50 of a queue-free run IS the service floor
    mean_service_s = probe["p50_ms"] * 1e-3
    mu = 1.0 / mean_service_s
    slo_s = max(0.005, 12.0 * mean_service_s)

    rows: List[Row] = []
    configs = []
    for frac in LOAD_FRACS:
        rate = frac * mu
        entry = {"load_frac": frac, "rate_per_s": rate}
        for policy in ("slo", "naive"):
            # --trace records the peak rung's slo pass (the run the
            # regression claims are about) without touching the numbers
            tp = TRACE_PATH if (trace and policy == "slo"
                                and frac == LOAD_FRACS[-1]) else None
            entry[policy] = _serve(store, heads, cap, task, models, rate,
                                   slo_s, n_requests, policy, max_batch,
                                   docs_per_req, trace_path=tp)
        configs.append(entry)
        s, n = entry["slo"], entry["naive"]
        rows.append((
            f"traffic/load{frac}",
            (s["p50_ms"] or 0.0) * 1e3,        # us per request (p50)
            f"p99_ms={s['p99_ms']:.3f};goodput={s['goodput']:.3f};"
            f"naive_p99_ms={n['p99_ms']:.3f};"
            f"naive_goodput={n['goodput']:.3f}"))

    peak = configs[-1]
    payload = {
        "bench": "traffic",
        "scenario": {**scenario, "requests": n_requests,
                     "max_batch": max_batch,
                     "docs_per_req": docs_per_req,
                     "capacity_pages": cap, "pages": store.num_pages(),
                     "zipf": ZIPF, "seed": SEED,
                     "load_fracs": list(LOAD_FRACS),
                     "slo_ms": slo_s * 1e3, "mu_per_s": mu,
                     "smoke": smoke},
        "configs": configs,
        # zero-tolerance internal claims (virtual clock: deterministic)
        "slo_beats_naive_p99_at_peak":
            peak["slo"]["p99_ms"] is not None
            and peak["naive"]["p99_ms"] is not None
            and peak["slo"]["p99_ms"] < peak["naive"]["p99_ms"],
        "slo_goodput_no_worse_at_peak":
            peak["slo"]["goodput"] >= peak["naive"]["goodput"],
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    return rows


def main() -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small fast configuration for CI")
    ap.add_argument("--trace", action="store_true",
                    help="record the peak-rung slo pass with a "
                         "clock-bound tracer and write "
                         "BENCH_traffic_trace.json (Chrome-trace form; "
                         "BENCH_traffic.json stays byte-identical)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke, trace=args.trace)
    if args.trace:
        print(f"# wrote {os.path.abspath(TRACE_PATH)}")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    with open(JSON_PATH) as f:
        payload = json.load(f)
    if not payload["slo_beats_naive_p99_at_peak"]:
        print("# WARN SLO-aware formation did NOT beat naive dispatch "
              "on p99 at the highest load rung")
    if not payload["slo_goodput_no_worse_at_peak"]:
        print("# WARN SLO-aware goodput lost to naive dispatch at the "
              "highest load rung")
    print(f"# wrote {os.path.abspath(JSON_PATH)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
