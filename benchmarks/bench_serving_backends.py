"""Serving-backend benchmarks -> BENCH_serving.json + BENCH_storage.json
+ BENCH_sharding.json.

Axis 1 (compute): numpy vs device.  Serves the paper's multi-model
word2vec traffic twice per pool capacity — once with host
materialization (``backend="numpy"``) and once straight from the HBM
page slab through the dedup kernels (``backend="device"``) — and
records batches/sec plus per-batch latency percentiles.  Per-batch
latency is what the engine's stats record: virtual storage seconds for
the batch's page faults plus wall compute seconds.

The ``capacity_frac < 1`` rows are the fig-8 "working set exceeds the
pool" regime, where every batch faults pages; the paper's claim under
test is that executing against the deduplicated layout keeps the compute
path ahead of (or level with) host re-densification even there.

Axis 2 (storage): local dir vs SQLite vs simulated object store.  The
same traffic is served device-backend out of a store *reopened live*
from each ``repro.storage`` backend, with pool misses charged from that
backend's own ``microbench()``-calibrated StorageModel (the virtual
clock) and page faults issued as grouped ``get_pages`` batches.  The
claim under test: the grouped miss path amortizes the relational
backend's per-request overhead, so SQLite's p50 stays within 10% of the
``file://`` backend even in the all-miss fig-8 regime (``objsim`` shows
what a ~20 ms-seek remote tier does to the same traffic).  Written to
BENCH_storage.json.

Axis 3 (sharding): shard count x placement policy.  The same traffic is
served through a :class:`ShardedWeightServer` at 1/2/4 shards with the
per-shard slab capacity held FIXED below the total working set (one
accelerator's HBM doesn't grow when you add accelerators) — the
"working set exceeds one shard" regime.  Claims under test: adding a
second shard beats one thrashing slab on p50, and the sharer-weighted
placement's fetch-channel p50 (deterministic virtual clock: storage
misses + cross-shard borrow traffic) never loses to the hash-mod
baseline, because replicating the hot shared pages and homing each
model's singletons together keeps batches on-shard.  Written to
BENCH_sharding.json.

Run standalone (``python -m benchmarks.bench_serving_backends [--smoke]``)
or through ``benchmarks.run``.  Always writes the JSON files at the
repo root so CI tracks the perf trajectory PR over PR.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import List

import numpy as np

from .common import Row, word2vec_scenario
from repro.core.store import ModelStore
from repro.serving.engine import (EmbeddingServingEngine, ServeStats,
                                  StorageModel, WeightServer)
from repro.storage import (LocalDirBackend, ObjectStoreSimBackend,
                           SQLiteBackend)

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_serving.json")
STORAGE_JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                                 "BENCH_storage.json")
SHARDING_JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                                  "BENCH_sharding.json")
TRANSFER_JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                                  "BENCH_transfer.json")


def _traffic(task, num_models, batches, batch_size, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for b in range(batches):
        v = int(rng.integers(0, num_models))
        docs, _ = task.sample(batch_size, variant=v, seed=20_000 + b)
        out.append((f"w2v-v{v}", docs))
    return out


def _serve(store, heads, traffic, cap, backend, warmup=4, reps=3):
    """Serve the same traffic ``reps`` times on one warm engine and keep
    the best rep (the repo's ``timed()`` convention: OS noise on shared
    runners only ever adds time)."""
    server = WeightServer(store, cap, "optimized_mru", StorageModel("dram"),
                          backend=backend)
    engine = EmbeddingServingEngine(server, heads, scheduler="round_robin",
                                    overlap=False)

    for model, docs in traffic[:warmup]:   # jit warmup / pool warm
        engine.submit(model, docs)
    engine.run()

    best = None
    for rep in range(reps):
        engine.stats = ServeStats(overlapped=engine.overlap)
        server.pool.reset_stats()
        if backend == "device":
            loads0 = server.device_pool.loads
            evicts0 = server.device_pool.evicts
        for model, docs in traffic:        # same traffic every rep
            engine.submit(model, docs)
        t0 = time.perf_counter()
        stats = engine.run()
        wall = time.perf_counter() - t0
        lat = np.asarray(stats.latencies)
        out = {
            "batches_per_sec": stats.batches / max(wall, 1e-9),
            "p50_ms": float(np.percentile(lat, 50)) * 1e3,
            "p99_ms": float(np.percentile(lat, 99)) * 1e3,
            "hit_ratio": server.pool.hit_ratio,
            "fetch_ms": stats.fetch_seconds * 1e3,
            "compute_ms": stats.compute_seconds * 1e3,
        }
        if backend == "device":
            out["device_batches"] = stats.device_batches
            out["dense_fallbacks"] = stats.dense_fallbacks
            out["slab_loads"] = server.device_pool.loads - loads0
            out["slab_evicts"] = server.device_pool.evicts - evicts0
        if best is None or out["p50_ms"] < best["p50_ms"]:
            best = out
    return best


def run_serving(smoke: bool = False) -> List[Row]:
    if smoke:
        scenario = dict(num_models=4, vocab=1024, d=64)
        batches, batch_size = 12, 64
        fracs = (0.5, 1.0)
    else:
        scenario = dict(num_models=6, vocab=4096, d=128)
        batches, batch_size = 30, 128
        fracs = (0.25, 0.5, 1.0)
    task, store, heads, _ = word2vec_scenario(**scenario)
    pages = store.num_pages()
    traffic = _traffic(task, scenario["num_models"], batches, batch_size)

    # Per-batch page working sets (what must co-reside in the slab for a
    # batch to serve off the device).  Capacities are floored just above
    # the worst batch: the fig-8 regime is TOTAL working set > pool >
    # one batch — every batch faults pages but never tears the slab.
    probe = WeightServer(store, 2)
    worst = max(len(probe.embedding_rows_pages(m, "embedding",
                                               np.unique(docs)))
                for m, docs in traffic)
    floor = worst + 1

    rows: List[Row] = []
    configs = []
    seen_caps = set()
    for frac in fracs:
        cap = min(pages, max(floor, int(pages * frac)))
        if cap in seen_caps:               # floor collapsed two fracs
            continue
        seen_caps.add(cap)
        res = {"capacity_frac": frac, "capacity_pages": cap,
               "worst_batch_pages": worst}
        for backend in ("numpy", "device"):
            res[backend] = _serve(store, heads, traffic, cap, backend)
        res["device_le_numpy_p50"] = \
            res["device"]["p50_ms"] <= res["numpy"]["p50_ms"]
        configs.append(res)
        for backend in ("numpy", "device"):
            r = res[backend]
            rows.append((
                f"serving_backends/pool{frac}/{backend}",
                r["p50_ms"] * 1e3,          # us per batch (p50)
                f"bps={r['batches_per_sec']:.1f};p99_ms={r['p99_ms']:.3f};"
                f"hit={r['hit_ratio']:.3f}"))

    payload = {
        "bench": "serving_backends",
        "scenario": {**scenario, "batches": batches,
                     "batch_size": batch_size, "pages": pages,
                     "storage": "dram", "smoke": smoke},
        "configs": configs,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    return rows


# ------------------------------------------------------ storage-axis bench --
def _serve_from_backend(backend, heads, traffic, cap, storage,
                        warmup=4, reps=3):
    """Reopen the store live from ``backend`` and serve the traffic
    device-backend with the calibrated virtual clock; best-of-reps."""
    opened = ModelStore.open(backend)
    server = WeightServer(opened, cap, "optimized_mru", storage,
                          backend="device")
    engine = EmbeddingServingEngine(server, heads, scheduler="round_robin",
                                    overlap=True)
    for model, docs in traffic[:warmup]:
        engine.submit(model, docs)
    engine.run()

    best = None
    for _ in range(reps):
        engine.stats = ServeStats(overlapped=engine.overlap)
        engine.timeline.fetch_clock = engine.timeline.compute_clock = 0.0
        server.pool.reset_stats()
        for model, docs in traffic:
            engine.submit(model, docs)
        t0 = time.perf_counter()
        stats = engine.run()
        wall = time.perf_counter() - t0
        lat = np.asarray(stats.latencies)
        out = {
            "batches_per_sec": stats.batches / max(wall, 1e-9),
            "p50_ms": float(np.percentile(lat, 50)) * 1e3,
            "p99_ms": float(np.percentile(lat, 99)) * 1e3,
            "hit_ratio": server.pool.hit_ratio,
            "fetch_ms": stats.fetch_seconds * 1e3,
            "compute_ms": stats.compute_seconds * 1e3,
            "device_batches": stats.device_batches,
            "dense_fallbacks": stats.dense_fallbacks,
        }
        if best is None or out["p50_ms"] < best["p50_ms"]:
            best = out
    return best


def run(smoke: bool = False) -> List[Row]:
    """All axes (what ``benchmarks.run`` invokes): compute backends ->
    BENCH_serving.json, storage backends -> BENCH_storage.json, shard
    count x placement -> BENCH_sharding.json, transfer path x miss rate
    -> BENCH_transfer.json."""
    return run_serving(smoke) + run_storage(smoke) + run_sharding(smoke) \
        + run_transfer(smoke)


# ----------------------------------------------------- transfer-axis bench --
def _transfer_scenario(num_models, vocab, d, seed=0,
                       block_shape=(32, 32), blocks_per_page=4):
    """N variants sharing one base embedding but each fine-tuning its
    OWN row stripe: any batch touches the shared pages plus exactly its
    model's private stripe, so per-batch cover ≈ half the union and the
    capacity ladder really sweeps the miss rate (batch ⊂ pool ⊂ union —
    the fig-8 regime).  The word2vec scenario can't produce this shape:
    its variants dedup so aggressively that every batch covers nearly
    the whole page universe."""
    from .common import store_config

    rng = np.random.default_rng(seed)
    base = (rng.standard_normal((vocab, d)) * 0.05).astype(np.float32)
    cfg = store_config(base, block_shape=block_shape,
                       blocks_per_page=blocks_per_page)
    store = ModelStore(cfg)
    heads = {}
    for v in range(num_models):
        emb = base.copy()
        lo, hi = v * vocab // num_models, (v + 1) * vocab // num_models
        emb[lo:hi] += (rng.standard_normal((hi - lo, d)) * 0.5
                       ).astype(np.float32)
        name = f"w2v-v{v}"
        store.register(name, {"embedding": emb})
        heads[name] = (rng.standard_normal((d, 16)) * 0.1
                       ).astype(np.float32)
    return store, heads


def _transfer_traffic(num_models, vocab, batches, batch_size,
                      seq=8, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for b in range(batches):
        v = int(rng.integers(0, num_models))
        docs = rng.integers(0, vocab, size=(batch_size, seq))
        out.append((f"w2v-v{v}", docs.astype(np.int64)))
    return out


def _serve_transfer(store, heads, traffic, cap, transfer, hbm,
                    warmup=4, reps=5, overlap=False):
    """One transfer-mode run with the host<->HBM channel ON the virtual
    clock (charge_transfer), calibrated once and shared across both
    modes so the only clock difference is per-page seeks vs one seek
    per group.  The headline (claim) runs are SERIAL — per-batch latency
    is the batch's own fetch+compute service time, the same no-queueing
    convention as the sharding axis (an overlapped timeline measures
    queue depth, which *rewards* a slower fetch channel).  ``overlap=
    True`` is the double-buffer demonstration run: fifo keeps the queue
    head predictable so prestaging engages, and overlap_fraction proves
    the next batch's transfer really rides under compute."""
    server = WeightServer(store, cap, "optimized_mru", StorageModel("dram"),
                          backend="device", transfer=transfer,
                          charge_transfer=True, hbm=hbm,
                          kernel_mode="xla")
    engine = EmbeddingServingEngine(server, heads, scheduler="fifo",
                                    overlap=overlap)
    for model, docs in traffic[:warmup]:
        engine.submit(model, docs)
    engine.run()

    # Percentiles POOL the reps instead of best-of: the pool trajectory
    # (and so the per-batch virtual clock) is deterministic and
    # identical between the two transfer modes, so pooled percentiles
    # compare PAIRED batches — best-of-rep would compare different reps.
    lats, flats = [], []
    best_bps = 0.0
    device_batches = fallbacks = 0
    agg = ServeStats()
    for _ in range(reps):
        engine.stats = ServeStats(overlapped=engine.overlap)
        engine.timeline.fetch_clock = engine.timeline.compute_clock = 0.0
        server.pool.reset_stats()
        for model, docs in traffic:
            engine.submit(model, docs)
        t0 = time.perf_counter()
        stats = engine.run()
        wall = time.perf_counter() - t0
        best_bps = max(best_bps, stats.batches / max(wall, 1e-9))
        lats.extend(stats.latencies)
        flats.extend(stats.fetch_latencies)
        agg.transfer_seconds += stats.transfer_seconds
        agg.transfer_pages += stats.transfer_pages
        agg.transfer_groups += stats.transfer_groups
        agg.transfer_bytes += stats.transfer_bytes
        agg.transfer_overlapped_bytes += stats.transfer_overlapped_bytes
        agg.group_sizes.extend(stats.group_sizes)
        device_batches += stats.device_batches
        fallbacks += stats.dense_fallbacks
    lat, flat = np.asarray(lats), np.asarray(flats)
    return {
        "batches_per_sec": best_bps,
        "p50_ms": float(np.percentile(lat, 50)) * 1e3,
        "p99_ms": float(np.percentile(lat, 99)) * 1e3,
        "fetch_p50_ms": float(np.percentile(flat, 50)) * 1e3,
        "fetch_p99_ms": float(np.percentile(flat, 99)) * 1e3,
        "miss_rate": 1.0 - server.pool.hit_ratio,
        "hit_ratio": server.pool.hit_ratio,
        "transfer_ms": agg.transfer_seconds * 1e3,
        "transfer_pages": agg.transfer_pages,
        "transfer_ops": agg.transfer_groups,
        "mean_group_size": agg.mean_group_size,
        "overlap_fraction": agg.overlap_fraction,
        "device_batches": device_batches,
        "dense_fallbacks": fallbacks,
    }


def run_transfer(smoke: bool = False) -> List[Row]:
    """per_page vs grouped host->HBM movement across a miss-rate ladder
    -> BENCH_transfer.json.

    Capacity fracs below 1.0 sweep the miss rate: the smaller the pool,
    the more pages every batch faults, and the more per-page seeks the
    grouped path's single seek amortizes away — so grouped p50 must win
    at every rung, with the gap *widening* as capacity shrinks (the
    fig-8 working-set-exceeds-pool regime)."""
    from repro.serving.device_pool import DevicePagePool

    if smoke:
        scenario = dict(num_models=4, vocab=2048, d=64)
        batches, batch_size = 14, 48
        fracs = (0.55, 0.7, 0.85)
    else:
        scenario = dict(num_models=4, vocab=4096, d=128)
        batches, batch_size = 24, 96
        fracs = (0.55, 0.7, 0.85)
    store, heads = _transfer_scenario(**scenario)
    pages = store.num_pages()
    traffic = _transfer_traffic(scenario["num_models"], scenario["vocab"],
                                batches, batch_size)

    probe = WeightServer(store, 2)
    worst = max(len(probe.embedding_rows_pages(m, "embedding",
                                               np.unique(docs)))
                for m, docs in traffic)
    floor = worst + 1

    # ONE measured host<->HBM channel, shared by both transfer modes: a
    # blocking bandwidth sweep over group sizes (bytes/s vs. n) fitted
    # to seconds = seek + bytes/bandwidth (serving/transfer.py).  xla
    # mode is the accelerator-shaped path off-TPU — a REAL device slab,
    # so a per-page miss really pays a device_put plus a slab-sized
    # functional update per page, which is exactly what grouping kills.
    cal_pool = DevicePagePool(store, max(floor, 8), kernel_mode="xla")
    hbm = cal_pool.transfer.storage_model()       # blocking measure() sweep
    del cal_pool

    rows: List[Row] = []
    configs = []
    seen_caps = set()
    for frac in fracs:
        cap = min(pages - 1, max(floor, int(pages * frac)))
        if cap in seen_caps:
            continue
        seen_caps.add(cap)
        entry = {"capacity_frac": frac, "capacity_pages": cap,
                 "worst_batch_pages": worst}
        for transfer in ("per_page", "grouped"):
            res = _serve_transfer(store, heads, traffic, cap, transfer, hbm)
            entry[transfer] = res
            rows.append((
                f"transfer/pool{frac}/{transfer}",
                res["p50_ms"] * 1e3,            # us per batch (p50)
                f"miss={res['miss_rate']:.3f};"
                f"group={res['mean_group_size']:.1f};"
                f"fetch_p50_ms={res['fetch_p50_ms']:.3f}"))
        # double-buffer demonstration: same grouped server driven by the
        # overlapped engine — prestaged bytes ride under compute
        entry["grouped_overlap"] = _serve_transfer(
            store, heads, traffic, cap, "grouped", hbm, overlap=True)
        entry["grouped_le_per_page_p50"] = \
            entry["grouped"]["p50_ms"] <= entry["per_page"]["p50_ms"] + 1e-9
        entry["grouped_le_per_page_fetch_p50"] = \
            entry["grouped"]["fetch_p50_ms"] \
            <= entry["per_page"]["fetch_p50_ms"] + 1e-9
        entry["fetch_gap_ms"] = entry["per_page"]["fetch_p50_ms"] \
            - entry["grouped"]["fetch_p50_ms"]
        entry["overlap_engaged"] = \
            entry["grouped_overlap"]["overlap_fraction"] > 0.0
        configs.append(entry)

    # fig-8 shape: the grouped win grows as capacity shrinks
    by_cap = sorted(configs, key=lambda e: e["capacity_pages"])
    gap_widens = by_cap[0]["fetch_gap_ms"] >= by_cap[-1]["fetch_gap_ms"] \
        - 1e-9 if len(by_cap) > 1 else True
    payload = {
        "bench": "transfer",
        "scenario": {**scenario, "batches": batches,
                     "batch_size": batch_size, "pages": pages,
                     "storage": "dram", "smoke": smoke},
        "hbm_channel": {"bandwidth_mbps": hbm.bw / 1e6,
                        "seek_us": hbm.seek * 1e6},
        "configs": configs,
        "grouped_le_per_page_p50_all": all(
            e["grouped_le_per_page_p50"] for e in configs),
        "grouped_le_per_page_fetch_p50_all": all(
            e["grouped_le_per_page_fetch_p50"] for e in configs),
        "gap_widens_as_capacity_shrinks": gap_widens,
        "overlap_engaged_all": all(e["overlap_engaged"] for e in configs),
    }
    with open(TRANSFER_JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    return rows


# ----------------------------------------------------- sharding-axis bench --
def _serve_sharded(store, heads, traffic, server_fn, warmup=4, reps=3):
    """Serial engine (per-batch latency = the batch's own fetch+compute
    service time, no queueing ambiguity) on a warm server; best-of-reps
    on wall p50.  The fetch-channel latencies are the virtual clock —
    deterministic, so placement policies compare noise-free."""
    server = server_fn()
    engine = EmbeddingServingEngine(server, heads, scheduler="round_robin",
                                    overlap=False)
    for model, docs in traffic[:warmup]:
        engine.submit(model, docs)
    engine.run()
    for model, docs in traffic:            # warm the steady-state residency
        engine.submit(model, docs)
    engine.run()

    best = None
    for _ in range(reps):
        engine.stats = ServeStats(overlapped=engine.overlap)
        server.pool.reset_stats()
        # server.stats accumulates across warmup+reps: report per-rep
        # deltas so the JSON's borrow numbers describe ONE traffic pass
        b_pages0 = server.stats.borrow_pages
        b_secs0 = server.stats.borrow_seconds
        shard0 = dict(server.stats.shard_batches)
        for model, docs in traffic:
            engine.submit(model, docs)
        t0 = time.perf_counter()
        stats = engine.run()
        wall = time.perf_counter() - t0
        lat = np.asarray(stats.latencies)
        flat = np.asarray(stats.fetch_latencies)
        out = {
            "batches_per_sec": stats.batches / max(wall, 1e-9),
            "p50_ms": float(np.percentile(lat, 50)) * 1e3,
            "p99_ms": float(np.percentile(lat, 99)) * 1e3,
            "fetch_p50_ms": float(np.percentile(flat, 50)) * 1e3,
            "fetch_p99_ms": float(np.percentile(flat, 99)) * 1e3,
            "hit_ratio": server.pool.hit_ratio,
            "fetch_ms": stats.fetch_seconds * 1e3,
            "device_batches": stats.device_batches,
            "dense_fallbacks": stats.dense_fallbacks,
            "borrow_pages": server.stats.borrow_pages - b_pages0,
            "borrow_ms": (server.stats.borrow_seconds - b_secs0) * 1e3,
            "shard_batches": {
                str(k): v - shard0.get(k, 0) for k, v in sorted(
                    server.stats.shard_batches.items())},
        }
        if best is None or out["p50_ms"] < best["p50_ms"]:
            best = out
    return best


def run_sharding(smoke: bool = False) -> List[Row]:
    """shard count x placement -> BENCH_sharding.json."""
    from repro.serving.shard_pool import ShardedWeightServer

    if smoke:
        scenario = dict(num_models=4, vocab=2048, d=64)
        batches, batch_size = 16, 96
        shard_counts = (1, 2)
    else:
        scenario = dict(num_models=6, vocab=4096, d=128)
        batches, batch_size = 30, 128
        shard_counts = (1, 2, 4)
    task, store, heads, _ = word2vec_scenario(**scenario)
    pages = store.num_pages()
    traffic = _traffic(task, scenario["num_models"], batches, batch_size)

    probe = WeightServer(store, 2)
    worst = max(len(probe.embedding_rows_pages(m, "embedding",
                                               np.unique(docs)))
                for m, docs in traffic)
    # Fixed PER-SHARD capacity below the total working set: every batch
    # fits one shard's slab, the pool as a whole doesn't — one slab
    # churns (the fig-8 floor), a mesh partitions its way out.
    cap = min(pages - 1, max(worst + 1, int(pages * 0.8)))
    storage = StorageModel("hdd")        # miss cost dominates the clock

    rows: List[Row] = []
    configs = []
    for shards in shard_counts:
        entry = {"shards": shards, "capacity_per_shard": cap}
        for placement in ("hash", "sharers"):
            res = _serve_sharded(
                store, heads, traffic,
                lambda: ShardedWeightServer(
                    store, cap, "optimized_mru", storage,
                    shards=shards, placement=placement))
            entry[placement] = res
            rows.append((
                f"sharding/s{shards}/{placement}",
                res["p50_ms"] * 1e3,            # us per batch (p50)
                f"fetch_p50_ms={res['fetch_p50_ms']:.3f};"
                f"hit={res['hit_ratio']:.3f};"
                f"borrows={res['borrow_pages']}"))
        # placement claim on the deterministic fetch channel
        entry["sharers_le_hash_fetch_p50"] = \
            entry["sharers"]["fetch_p50_ms"] \
            <= entry["hash"]["fetch_p50_ms"] + 1e-9
        configs.append(entry)

    by_shards = {e["shards"]: e for e in configs}
    scaling_ok = by_shards[2]["sharers"]["p50_ms"] \
        <= by_shards[1]["sharers"]["p50_ms"]
    payload = {
        "bench": "sharding",
        "scenario": {**scenario, "batches": batches,
                     "batch_size": batch_size, "pages": pages,
                     "capacity_per_shard": cap,
                     "worst_batch_pages": worst,
                     "storage": "hdd", "smoke": smoke},
        "configs": configs,
        "sharers_le_hash_fetch_p50_all": all(
            e["sharers_le_hash_fetch_p50"] for e in configs
            if e["shards"] > 1),
        "two_shard_p50_le_one_shard": scaling_ok,
    }
    with open(SHARDING_JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    return rows


def run_storage(smoke: bool = False) -> List[Row]:
    """local vs sqlite vs objsim serving -> BENCH_storage.json."""
    if smoke:
        scenario = dict(num_models=4, vocab=1024, d=64)
        batches, batch_size = 12, 64
    else:
        scenario = dict(num_models=6, vocab=2048, d=64)
        batches, batch_size = 24, 96
    task, store, heads, _ = word2vec_scenario(**scenario)
    pages = store.num_pages()
    traffic = _traffic(task, scenario["num_models"], batches, batch_size)
    bh, bw = store.cfg.dedup.block_shape
    page_bytes = store.cfg.blocks_per_page * bh * bw \
        * store.native_page_dtype().itemsize

    probe = WeightServer(store, 2)
    worst = max(len(probe.embedding_rows_pages(m, "embedding",
                                               np.unique(docs)))
                for m, docs in traffic)
    # the fig-8 all-miss regime: one batch fits, the working set doesn't
    cap = min(pages, worst + 1)

    tmp = tempfile.mkdtemp(prefix="bench_storage_")
    rows: List[Row] = []
    results = {}
    try:
        backends = [
            ("file", LocalDirBackend(os.path.join(tmp, "file_store"))),
            ("sqlite", SQLiteBackend(os.path.join(tmp, "models.db"))),
            ("objsim", ObjectStoreSimBackend()),  # ~20 ms seek, 200 MB/s
        ]
        for name, backend in backends:
            store.save(backend)
            prof = backend.microbench(page_bytes=page_bytes)
            storage = StorageModel(kind=f"calibrated:{name}",
                                   bandwidth=prof.bandwidth, seek=prof.seek)
            res = _serve_from_backend(backend, heads, traffic, cap, storage)
            res["profile"] = {"bandwidth_mbps": prof.bandwidth / 1e6,
                              "seek_us": prof.seek * 1e6,
                              "page_bytes": page_bytes}
            if name == "objsim":
                res["backend_get_calls"] = backend.get_calls
                res["backend_pages_fetched"] = backend.pages_fetched
            results[name] = res
            rows.append((
                f"storage_backends/{name}/device",
                res["p50_ms"] * 1e3,            # us per batch (p50)
                f"bps={res['batches_per_sec']:.1f};"
                f"p99_ms={res['p99_ms']:.3f};hit={res['hit_ratio']:.3f};"
                f"bw={prof.bandwidth/1e6:.0f}MB/s"))
            backend.close()
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    sqlite_ok = results["sqlite"]["p50_ms"] \
        <= 1.10 * results["file"]["p50_ms"]
    payload = {
        "bench": "storage_backends",
        "scenario": {**scenario, "batches": batches,
                     "batch_size": batch_size, "pages": pages,
                     "capacity_pages": cap, "worst_batch_pages": worst,
                     "page_bytes": page_bytes, "smoke": smoke},
        "backends": results,
        "sqlite_within_10pct_of_file_p50": sqlite_ok,
    }
    with open(STORAGE_JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    return rows


def main() -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small fast configuration for CI")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    with open(JSON_PATH) as f:
        payload = json.load(f)
    bad = [c for c in payload["configs"]
           if c["capacity_frac"] < 1.0 and not c["device_le_numpy_p50"]]
    for c in bad:
        print(f"# WARN device p50 {c['device']['p50_ms']:.3f}ms > numpy "
              f"{c['numpy']['p50_ms']:.3f}ms at frac={c['capacity_frac']}")
    with open(STORAGE_JSON_PATH) as f:
        spayload = json.load(f)
    if not spayload["sqlite_within_10pct_of_file_p50"]:
        print(f"# WARN sqlite p50 "
              f"{spayload['backends']['sqlite']['p50_ms']:.3f}ms > 1.1x "
              f"file p50 {spayload['backends']['file']['p50_ms']:.3f}ms")
    with open(SHARDING_JSON_PATH) as f:
        shpayload = json.load(f)
    if not shpayload["sharers_le_hash_fetch_p50_all"]:
        print("# WARN sharers placement lost the fetch-channel p50 to "
              "hash-mod at some shard count")
    if not shpayload["two_shard_p50_le_one_shard"]:
        print("# WARN 2-shard p50 did not beat the 1-shard thrash floor")
    with open(TRANSFER_JSON_PATH) as f:
        tpayload = json.load(f)
    if not tpayload["grouped_le_per_page_p50_all"]:
        print("# WARN grouped transfer lost the p50 to per_page at some "
              "miss rate")
    if not tpayload["gap_widens_as_capacity_shrinks"]:
        print("# WARN grouped-vs-per_page fetch gap did not widen as "
              "capacity shrank")
    print(f"# wrote {os.path.abspath(JSON_PATH)}")
    print(f"# wrote {os.path.abspath(STORAGE_JSON_PATH)}")
    print(f"# wrote {os.path.abspath(SHARDING_JSON_PATH)}")
    print(f"# wrote {os.path.abspath(TRANSFER_JSON_PATH)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
