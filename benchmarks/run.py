# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import importlib
import sys
import time

MODULES = [
    "tab1_word2vec_serving",
    "tab2_text_classification",
    "tab3_extreme_classification",
    "tab4_heterogeneous",
    "tab5_index_comparison",
    "tab6_lsh_threshold",
    "tab7_page_packing",
    "tab8_model_updates",
    "tab9_compression",
    "fig8_latency_curves",
    "fig13_validation_overheads",
    "fig14_cache_policies",
    "bench_serving_backends",
    "bench_faults",
    "bench_traffic",
    "bench_recovery",
    "roofline_table",
]


def main() -> None:
    only = sys.argv[1:] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    failures = []
    for name in MODULES:
        if only and not any(o in name for o in only):
            continue
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:              # keep the harness running
            failures.append((name, repr(e)))
            print(f"{name}/ERROR,0.0,{type(e).__name__}")
            continue
        for r, us, derived in rows:
            print(f"{r},{us:.1f},{derived}")
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
