"""Fault-injection serving benchmark -> BENCH_faults.json.

Serves identical multi-model embedding traffic out of one committed
store at increasing storage fault rates (0 / 5% / 10%: transient read
errors, bit-flip corruption, lock contention, latency spikes) through
the recovery layer (``storage/faults.py`` + the ModelStore retry /
verify / quarantine path, DESIGN.md §8) and records:

  * **bit-exactness** — the logits of every faulted run must equal the
    rate-0 run bit for bit.  Recovery is invisible to the math or it
    is not recovery.
  * **bounded tails** — per-batch latency p50/p99 per rate (virtual
    fetch seconds + wall compute; retry backoff and injected latency
    ride the clock's own ``fault`` channel).  The p99 at 10% faults
    must stay within a constant factor of the fault-free p99 — chaos
    costs backoff, never a cliff.
  * **recovery accounting** — retries / corrupt pages detected /
    quarantine re-fetches / virtual backoff seconds per rate.
  * **the naive path dies** — the same 10%-fault traffic served with
    the recovery layer disabled (zero retries, no verification) either
    crashes or silently serves corrupt logits; the benchmark records
    which, proving the layer is load-bearing.

Run standalone (``python -m benchmarks.bench_faults [--smoke]``) or
through ``benchmarks.run``.  Always writes BENCH_faults.json at the
repo root so CI tracks the chaos trajectory PR over PR.
"""
from __future__ import annotations

import json
import os
import time
from typing import List, Optional

import numpy as np

from .common import Row, word2vec_scenario
from repro.core.store import ModelStore
from repro.serving.engine import (EmbeddingServingEngine, StorageModel,
                                  WeightServer)
from repro.storage import MemoryBackend
from repro.storage.faults import (FaultInjectingBackend, FaultSpec,
                                  RetryPolicy, StorageFaultError)

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_faults.json")

#: chaos tail tolerance.  A tail batch legitimately absorbs a few
#: injected latency spikes (FaultSpec.latency_ms each) plus bounded
#: retry backoff — the claim under test is the absence of an UNBOUNDED
#: retry storm, so the bound is a factor over the fault-free p99 plus
#: an absolute grace of a handful of spikes.  A convergence bug (retry
#: loop thrashing, quarantine never draining) blows through this by
#: orders of magnitude.
P99_FACTOR = 3.0
P99_SPIKE_BUDGET = 4          # spikes the worst batch may absorb


def _traffic(task, num_models, batches, batch_size, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for b in range(batches):
        v = int(rng.integers(0, num_models))
        docs, _ = task.sample(batch_size, variant=v, seed=30_000 + b)
        out.append((f"w2v-v{v}", docs))
    return out


def _spec(rate: float, seed: int = 11) -> FaultSpec:
    """All fault kinds at ``rate`` (latency spikes at 2x: they are the
    cheap kind), one seed so every rate is its own deterministic run."""
    return FaultSpec(transient=rate, corrupt=rate, lock=rate,
                     torn=rate, latency=min(1.0, 2 * rate), seed=seed)


def _serve_chaos(inner: MemoryBackend, heads, traffic, cap: int,
                 rate: float, recover: bool = True):
    """One full traffic pass against a freshly wrapped backend; returns
    (per-run dict, stacked logits).  ``recover=False`` is the naive
    path: zero retries, verification forced off."""
    backend = FaultInjectingBackend(inner, _spec(rate)) if rate > 0 \
        else inner
    opened = ModelStore.open(backend)
    if not recover:
        opened.retry_policy = RetryPolicy(max_retries=0)
        opened.verify_pages = False
    server = WeightServer(opened, cap, "optimized_mru",
                          StorageModel("dram"), backend="device")
    engine = EmbeddingServingEngine(server, heads, scheduler="fifo",
                                    overlap=True)
    # No warmup pass: the host tier caches every page it has faulted, so
    # recovery only happens on FIRST touch — a warmup would absorb the
    # entire fault schedule outside the measured window.  Every rate
    # serves the identical cold-start traffic instead, so the runs stay
    # paired and the measured tail includes real recovery work.
    logits: List[np.ndarray] = []
    t0 = time.perf_counter()
    for model, docs in traffic:
        engine.submit(model, docs)
        engine.run(max_batches=1)          # one batch -> capture logits
        logits.append(np.asarray(engine.last_logits, np.float32))
    wall = time.perf_counter() - t0
    stats, fs = engine.stats, server.stats
    lat = np.asarray(stats.latencies)
    out = {
        "rate": rate,
        "batches": stats.batches,
        "batches_per_sec": stats.batches / max(wall, 1e-9),
        "p50_ms": float(np.percentile(lat, 50)) * 1e3,
        "p99_ms": float(np.percentile(lat, 99)) * 1e3,
        "hit_ratio": server.pool.hit_ratio,
        "retries": fs.retries,
        "corrupt_detected": fs.corrupt_detected,
        "refetch_pages": fs.refetch_pages,
        "degraded_batches": stats.degraded_batches,
        "fault_backoff_ms": fs.fault_backoff_seconds * 1e3,
        "injected": dict(getattr(backend, "injected", {})),
    }
    return out, np.concatenate([l.reshape(-1) for l in logits])


def run(smoke: bool = False) -> List[Row]:
    if smoke:
        scenario = dict(num_models=4, vocab=1024, d=64)
        batches, batch_size = 12, 64
        rates = (0.0, 0.05, 0.10)
    else:
        scenario = dict(num_models=6, vocab=2048, d=64)
        batches, batch_size = 24, 96
        rates = (0.0, 0.02, 0.05, 0.10)
    task, store, heads, _ = word2vec_scenario(**scenario)
    pages = store.num_pages()
    traffic = _traffic(task, scenario["num_models"], batches, batch_size)

    probe = WeightServer(store, 2)
    worst = max(len(probe.embedding_rows_pages(m, "embedding",
                                               np.unique(docs)))
                for m, docs in traffic)
    # the all-miss fig-8 regime: every batch faults pages, so every
    # batch actually exercises the injected backend
    cap = min(pages, worst + 1)

    inner = MemoryBackend()
    store.save(inner)

    rows: List[Row] = []
    configs = []
    baseline: Optional[np.ndarray] = None
    for rate in rates:
        res, logits = _serve_chaos(inner, heads, traffic, cap, rate)
        if baseline is None:
            baseline = logits
            res["logits_exact"] = True
        else:
            res["logits_exact"] = bool(np.array_equal(baseline, logits))
        configs.append(res)
        rows.append((
            f"faults/rate{rate}",
            res["p50_ms"] * 1e3,               # us per batch (p50)
            f"p99_ms={res['p99_ms']:.3f};retries={res['retries']};"
            f"corrupt={res['corrupt_detected']};"
            f"exact={int(res['logits_exact'])}"))

    # -- the naive path dies ------------------------------------------------
    worst_rate = rates[-1]
    naive = {"rate": worst_rate, "recovery": False}
    try:
        res, logits = _serve_chaos(inner, heads, traffic, cap, worst_rate,
                                   recover=False)
        naive["crashed"] = False
        naive["logits_exact"] = bool(np.array_equal(baseline, logits))
        naive["corrupt_detected"] = res["corrupt_detected"]
    except (StorageFaultError, KeyError) as exc:
        naive["crashed"] = True
        naive["error"] = type(exc).__name__
        naive["logits_exact"] = False
    # either failure mode proves the recovery layer is load-bearing
    naive["dies"] = naive["crashed"] or not naive["logits_exact"]

    p99_0 = configs[0]["p99_ms"]
    p99_worst = configs[-1]["p99_ms"]
    grace_ms = P99_SPIKE_BUDGET * _spec(0.10).latency_ms
    payload = {
        "bench": "faults",
        "scenario": {**scenario, "batches": batches,
                     "batch_size": batch_size, "pages": pages,
                     "capacity_pages": cap, "worst_batch_pages": worst,
                     "spec": str(_spec(0.10)), "smoke": smoke},
        "configs": configs,
        "naive": naive,
        "logits_exact_all": all(c["logits_exact"] for c in configs),
        "p99_bounded": p99_worst <= P99_FACTOR * p99_0 + grace_ms,
        "p99_factor_limit": P99_FACTOR,
        "p99_grace_ms": grace_ms,
        "naive_path_dies": naive["dies"],
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    return rows


def main() -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small fast configuration for CI")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    with open(JSON_PATH) as f:
        payload = json.load(f)
    if not payload["logits_exact_all"]:
        print("# WARN recovered serving was NOT bit-exact under faults")
    if not payload["p99_bounded"]:
        print(f"# WARN p99 under {payload['configs'][-1]['rate']:.0%} "
              f"faults exceeded {P99_FACTOR}x the fault-free p99")
    if not payload["naive_path_dies"]:
        print("# WARN the naive (no-recovery) path survived bit-exact — "
              "the fault schedule is too soft to prove anything")
    print(f"# wrote {os.path.abspath(JSON_PATH)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
